//! Per-peer failure detection: the Up → Suspect → Down state machine that
//! drives read-path failover (PR 7).
//!
//! Production clusters lose nodes; the paper's static placement assumes
//! they don't.  [`HealthMap`] closes the gap: every transport error against
//! a peer feeds [`HealthMap::record_failure`], consecutive failures walk
//! the peer Up → Suspect → Down, and the read path consults
//! [`HealthMap::order_candidates`] to try live replicas first.  Successes
//! (a served batch, a [`Response::Pong`]) reset the peer to Up.
//!
//! **Peer epochs** keep a restarted peer distinct from the incarnation
//! that failed: every sealed node stamps a process-unique epoch number
//! (see `NodeBuilder::seal`), `Ping`/`Pong` carry it, and a pong whose
//! epoch differs from the last one seen means "same address, new node" —
//! the health layer resets its view rather than trusting stale state.
//!
//! **Backoff** between retry rounds is exponential with deterministic
//! jitter from [`crate::util::prng::Prng`], so chaos tests replay the
//! exact same schedule from the same seed.
//!
//! The map is deliberately cheap: one mutex around a small `Vec` (peers
//! number in the hundreds, touches happen only on failures and probe
//! replies — the healthy hot path never takes this lock).

use std::sync::Mutex;
use std::time::Duration;

use crate::util::prng::Prng;

/// Liveness verdict for one peer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PeerState {
    /// No reason to doubt the peer.
    Up,
    /// Recent failures; still tried, but deprioritized behind Up peers.
    Suspect,
    /// Failure budget exhausted; skipped until evidence of life (a
    /// successful call or a pong) resurrects it.
    Down,
}

/// Tunables for the state machine and the retry/backoff schedule.
#[derive(Clone, Copy, Debug)]
pub struct HealthPolicy {
    /// Consecutive failures before Up → Suspect.
    pub suspect_after: u32,
    /// Consecutive failures before → Down.
    pub down_after: u32,
    /// How many times a single logical read may be re-routed to another
    /// holder before degrading to an error (`--retry-budget`).
    pub retry_budget: u32,
    /// Base backoff before retry round `n` is `base << n`, capped.
    pub backoff_base_ms: u64,
    pub backoff_cap_ms: u64,
}

impl Default for HealthPolicy {
    fn default() -> Self {
        HealthPolicy {
            suspect_after: 1,
            down_after: 2,
            retry_budget: 2,
            backoff_base_ms: 1,
            backoff_cap_ms: 100,
        }
    }
}

#[derive(Clone, Copy, Debug)]
struct PeerHealth {
    state: PeerState,
    /// Consecutive failures since the last success.
    failures: u32,
    /// Last epoch seen in a pong from this peer, if any.
    epoch: Option<u64>,
}

impl PeerHealth {
    fn fresh() -> PeerHealth {
        PeerHealth {
            state: PeerState::Up,
            failures: 0,
            epoch: None,
        }
    }
}

/// Cluster-wide peer health, shared by every reader thread of a node.
pub struct HealthMap {
    policy: HealthPolicy,
    peers: Mutex<Vec<PeerHealth>>,
    /// Jitter source for [`HealthMap::backoff`]; seeded per node so two
    /// nodes never thundering-herd a recovering peer in lockstep, yet each
    /// node's schedule is deterministic and replayable.
    jitter: Mutex<Prng>,
}

impl HealthMap {
    pub fn new(nodes: u32, policy: HealthPolicy, seed: u64) -> HealthMap {
        HealthMap {
            policy,
            peers: Mutex::new(vec![PeerHealth::fresh(); nodes as usize]),
            jitter: Mutex::new(Prng::new(seed)),
        }
    }

    pub fn policy(&self) -> &HealthPolicy {
        &self.policy
    }

    pub fn state(&self, peer: u32) -> PeerState {
        let peers = self.peers.lock().unwrap();
        peers.get(peer as usize).map_or(PeerState::Down, |p| p.state)
    }

    /// Record a transport error against `peer`.  Returns `true` exactly on
    /// the transition *into* Down (so the caller can count
    /// `peers_marked_down` and evict pooled sockets once, not per error).
    pub fn record_failure(&self, peer: u32) -> bool {
        let mut peers = self.peers.lock().unwrap();
        let Some(p) = peers.get_mut(peer as usize) else {
            return false;
        };
        p.failures = p.failures.saturating_add(1);
        let was = p.state;
        p.state = if p.failures >= self.policy.down_after {
            PeerState::Down
        } else if p.failures >= self.policy.suspect_after {
            PeerState::Suspect
        } else {
            PeerState::Up
        };
        was != PeerState::Down && p.state == PeerState::Down
    }

    /// Record a successful round trip with `peer`; resets it to Up.  Pass
    /// the peer's epoch when the reply carried one (a pong) — `None` for
    /// ordinary data replies, which prove liveness but not identity.
    pub fn record_success(&self, peer: u32, epoch: Option<u64>) {
        let mut peers = self.peers.lock().unwrap();
        if let Some(p) = peers.get_mut(peer as usize) {
            p.failures = 0;
            p.state = PeerState::Up;
            if epoch.is_some() {
                p.epoch = epoch;
            }
        }
    }

    /// Digest a [`Response::Pong`]: marks the peer Up and returns `true`
    /// iff the epoch changed from a previously-seen one — i.e. the peer
    /// restarted since we last identified it.
    pub fn note_pong(&self, peer: u32, epoch: u64) -> bool {
        let mut peers = self.peers.lock().unwrap();
        let Some(p) = peers.get_mut(peer as usize) else {
            return false;
        };
        let restarted = matches!(p.epoch, Some(prev) if prev != epoch);
        p.failures = 0;
        p.state = PeerState::Up;
        p.epoch = Some(epoch);
        restarted
    }

    /// Exponential backoff with deterministic jitter before retry round
    /// `attempt` (0-based): `base · 2^attempt`, saturating at
    /// `backoff_cap_ms`, plus up to +50% jitter so recovering peers aren't
    /// hammered in phase.
    ///
    /// The growth is a saturating *multiplication*, not a shift:
    /// `checked_shl` only rejects shift amounts ≥ 64 and silently drops
    /// high bits otherwise, so `base << attempt` collapses to a tiny (or
    /// zero) backoff once `base · 2^attempt` no longer fits in a `u64` —
    /// the exact opposite of backing off.
    pub fn backoff(&self, attempt: u32) -> Duration {
        let factor = 1u64
            .checked_shl(attempt.min(63))
            .expect("shift clamped below 64");
        let base = self
            .policy
            .backoff_base_ms
            .saturating_mul(factor)
            .min(self.policy.backoff_cap_ms)
            .max(1);
        let jitter = self.jitter.lock().unwrap().below(base / 2 + 1);
        Duration::from_millis(base + jitter)
    }

    /// Order replica holders for a read: `preferred` first if live, then
    /// the remaining Up/Suspect holders, Down holders last (still present —
    /// when *every* holder is down they are the only thing left to try
    /// before degrading).
    pub fn order_candidates(&self, holders: &[u32], preferred: u32) -> Vec<u32> {
        let peers = self.peers.lock().unwrap();
        let state = |n: u32| {
            peers
                .get(n as usize)
                .map_or(PeerState::Down, |p| p.state)
        };
        let mut live: Vec<u32> = Vec::with_capacity(holders.len());
        let mut down: Vec<u32> = Vec::new();
        // stable preferred-first rotation keeps load spread across holders
        let start = holders.iter().position(|&h| h == preferred).unwrap_or(0);
        for i in 0..holders.len() {
            let h = holders[(start + i) % holders.len()];
            if state(h) == PeerState::Down {
                down.push(h);
            } else {
                live.push(h);
            }
        }
        live.extend_from_slice(&down);
        live
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn map() -> HealthMap {
        HealthMap::new(4, HealthPolicy::default(), 0xFEED)
    }

    #[test]
    fn failures_walk_up_suspect_down_and_success_resets() {
        let h = map();
        assert_eq!(h.state(1), PeerState::Up);
        assert!(!h.record_failure(1), "first failure only suspects");
        assert_eq!(h.state(1), PeerState::Suspect);
        assert!(h.record_failure(1), "second failure transitions into Down");
        assert_eq!(h.state(1), PeerState::Down);
        assert!(!h.record_failure(1), "already Down: no second transition");
        h.record_success(1, None);
        assert_eq!(h.state(1), PeerState::Up);
        // out-of-range peers are reported Down, never panic
        assert_eq!(h.state(99), PeerState::Down);
        assert!(!h.record_failure(99));
    }

    #[test]
    fn pong_epoch_change_detects_restart() {
        let h = map();
        assert!(!h.note_pong(2, 100), "first sighting is not a restart");
        assert!(!h.note_pong(2, 100), "same epoch, same incarnation");
        assert!(h.note_pong(2, 101), "new epoch = restarted peer");
        assert_eq!(h.state(2), PeerState::Up);
        // a pong resurrects a Down peer
        h.record_failure(3);
        h.record_failure(3);
        assert_eq!(h.state(3), PeerState::Down);
        h.note_pong(3, 7);
        assert_eq!(h.state(3), PeerState::Up);
    }

    #[test]
    fn backoff_grows_caps_and_is_deterministic_per_seed() {
        let policy = HealthPolicy {
            backoff_base_ms: 2,
            backoff_cap_ms: 50,
            ..HealthPolicy::default()
        };
        let a = HealthMap::new(2, policy, 42);
        let b = HealthMap::new(2, policy, 42);
        let sched_a: Vec<Duration> = (0..8).map(|n| a.backoff(n)).collect();
        let sched_b: Vec<Duration> = (0..8).map(|n| b.backoff(n)).collect();
        assert_eq!(sched_a, sched_b, "same seed, same jittered schedule");
        // base doubles until the cap; jitter adds at most +50%
        for (n, d) in sched_a.iter().enumerate() {
            let base = (2u64 << n.min(16)).min(50);
            assert!(d.as_millis() as u64 >= base, "round {n}: {d:?} < {base}");
            assert!(d.as_millis() as u64 <= base + base / 2, "round {n}: {d:?}");
        }
        let c = HealthMap::new(2, policy, 43);
        let sched_c: Vec<Duration> = (0..8).map(|n| c.backoff(n)).collect();
        assert_ne!(sched_a, sched_c, "different seed, different jitter");
    }

    #[test]
    fn backoff_saturates_at_cap_for_huge_attempts_and_bases() {
        // Regression: the old shift-based growth used `checked_shl`, which
        // only rejects shift amounts >= 64 — it happily drops high bits, so
        // a large base at a large attempt collapsed toward 0ms instead of
        // pinning at the cap.  `2^63 << 1 == 0` is the canonical example.
        let policy = HealthPolicy {
            backoff_base_ms: 1 << 63,
            backoff_cap_ms: 1000,
            ..HealthPolicy::default()
        };
        let h = HealthMap::new(2, policy, 7);
        for attempt in [1, 2, 16, 63, 64, 200, u32::MAX] {
            let d = h.backoff(attempt).as_millis() as u64;
            assert!(
                (1000..=1500).contains(&d),
                "attempt {attempt}: {d}ms escaped the cap window"
            );
        }
        // small base, astronomically large attempt: still exactly cap+jitter
        let policy = HealthPolicy {
            backoff_base_ms: 3,
            backoff_cap_ms: 80,
            ..HealthPolicy::default()
        };
        let h = HealthMap::new(2, policy, 0xABCD);
        // pin the exact jittered sequence against a parallel PRNG: every
        // draw must be `cap + below(cap/2 + 1)` from the same seed stream
        let mut reference = Prng::new(0xABCD);
        for attempt in [100, 1000, u32::MAX - 1, u32::MAX] {
            let expect = 80 + reference.below(41);
            assert_eq!(
                h.backoff(attempt).as_millis() as u64,
                expect,
                "attempt {attempt}: jitter sequence diverged"
            );
        }
    }

    #[test]
    fn suspect_recovers_to_up_on_success_and_failure_count_resets() {
        let h = map();
        h.record_failure(1);
        assert_eq!(h.state(1), PeerState::Suspect);
        h.record_success(1, None);
        assert_eq!(h.state(1), PeerState::Up);
        // the consecutive-failure counter must reset too: one new failure
        // re-suspects but does NOT carry over toward Down
        assert!(!h.record_failure(1), "reset counter: not a Down transition");
        assert_eq!(h.state(1), PeerState::Suspect);
    }

    #[test]
    fn restart_epoch_bump_mid_backoff_window_resets_peer() {
        let h = map();
        h.note_pong(2, 500); // identify incarnation 500
        h.record_failure(2);
        h.record_failure(2);
        assert_eq!(h.state(2), PeerState::Down);
        // the prober is mid-backoff against the Down peer (draws consumed,
        // attempts mounting) when a pong with a NEW epoch lands: the peer
        // was replaced, not healed — note_pong must report the restart and
        // reset state so stale Down/failure history doesn't taint the
        // fresh incarnation
        let _ = h.backoff(3);
        let _ = h.backoff(4);
        assert!(h.note_pong(2, 501), "new epoch during backoff = restart");
        assert_eq!(h.state(2), PeerState::Up);
        assert!(!h.record_failure(2), "failure history cleared by restart");
        assert_eq!(h.state(2), PeerState::Suspect);
    }

    #[test]
    fn candidate_order_with_every_holder_down_keeps_all_and_rotation() {
        let h = map();
        for peer in [0u32, 1, 2] {
            h.record_failure(peer);
            h.record_failure(peer);
            assert_eq!(h.state(peer), PeerState::Down);
        }
        // nothing is dropped and the preferred-first rotation survives, so
        // a fully-dark replica set still gets a deterministic try order
        assert_eq!(h.order_candidates(&[0, 1, 2], 1), vec![1, 2, 0]);
        assert_eq!(h.order_candidates(&[0, 1, 2], 2), vec![2, 0, 1]);
        assert_eq!(h.order_candidates(&[0, 1, 2], 9), vec![0, 1, 2]);
    }

    #[test]
    fn candidate_order_prefers_live_peers_and_rotates_from_preferred() {
        let h = map();
        // all up: preferred-first rotation
        assert_eq!(h.order_candidates(&[0, 1, 2], 1), vec![1, 2, 0]);
        // unknown preferred falls back to holder order
        assert_eq!(h.order_candidates(&[0, 1, 2], 9), vec![0, 1, 2]);
        // a Down peer sinks to the back but is never dropped
        h.record_failure(1);
        h.record_failure(1);
        assert_eq!(h.order_candidates(&[0, 1, 2], 1), vec![2, 0, 1]);
        // Suspect peers still count as live (they may just be slow)
        h.record_failure(2);
        assert_eq!(h.state(2), PeerState::Suspect);
        assert_eq!(h.order_candidates(&[0, 1, 2], 0), vec![0, 2, 1]);
    }
}
