//! Interconnect cost model (virtual time).
//!
//! A remote read is a round trip: tiny request out, file payload back.  Each
//! node has one full-duplex NIC modelled as two FIFO `Resource` lanes (tx,
//! rx).  The fabric itself is non-blocking fat-tree (both testbeds, §6.1), so
//! contention happens at the endpoints — the standard assumption for these
//! topologies and the reason the paper's scaling is endpoint-limited.

use crate::sim::clock::{transfer_ns, SimNs};

/// Link/NIC parameters for one cluster interconnect.
#[derive(Clone, Copy, Debug)]
pub struct Fabric {
    /// One-way small-message latency.
    pub latency_ns: SimNs,
    /// Per-NIC bandwidth, bytes/s.
    pub bw: u64,
    /// Per-message software overhead (MPI stack, matching, registration).
    pub sw_overhead_ns: SimNs,
}

impl Fabric {
    /// Mellanox FDR InfiniBand: 56 Gb/s, sub-µs latency (GPU cluster).
    pub fn fdr_infiniband() -> Self {
        Fabric {
            latency_ns: 700, // 0.7 µs
            bw: 56_000_000_000 / 8,
            sw_overhead_ns: 1_500,
        }
    }

    /// Intel Omni-Path: 100 Gb/s, ~1 µs latency (CPU cluster).
    pub fn omni_path() -> Self {
        Fabric {
            latency_ns: 1_000,
            bw: 100_000_000_000 / 8,
            sw_overhead_ns: 1_500,
        }
    }

    /// Wire + software time to push `bytes` through one NIC.
    pub fn tx_service(&self, bytes: u64) -> SimNs {
        self.sw_overhead_ns + transfer_ns(bytes, self.bw)
    }

    /// End-to-end one-way time for `bytes`, endpoints uncontended.
    pub fn oneway_ns(&self, bytes: u64) -> SimNs {
        self.tx_service(bytes) + self.latency_ns
    }

    /// Uncontended request/response round trip: `req` bytes out, `resp` back.
    pub fn roundtrip_ns(&self, req: u64, resp: u64) -> SimNs {
        self.oneway_ns(req) + self.oneway_ns(resp)
    }
}

/// Small-message size of a FanStore read request (path + header).
pub const REQUEST_BYTES: u64 = 320;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::clock::{MS, NS_PER_SEC, US};

    #[test]
    fn fdr_large_message_hits_wire_rate() {
        let f = Fabric::fdr_infiniband();
        let bytes = 64u64 << 20;
        let t = f.oneway_ns(bytes);
        let gbps = bytes as f64 * 8.0 / (t as f64 / NS_PER_SEC as f64) / 1e9;
        assert!(gbps > 54.0 && gbps <= 56.0, "gbps {gbps}");
    }

    #[test]
    fn opa_faster_than_fdr_for_bulk() {
        let bytes = 8u64 << 20;
        assert!(
            Fabric::omni_path().oneway_ns(bytes) < Fabric::fdr_infiniband().oneway_ns(bytes)
        );
    }

    #[test]
    fn small_message_latency_bound() {
        let f = Fabric::fdr_infiniband();
        let t = f.roundtrip_ns(REQUEST_BYTES, 4096);
        assert!(t < 20 * US, "{t}"); // small files are latency, not bw, bound
    }

    #[test]
    fn roundtrip_is_sum_of_oneways() {
        let f = Fabric::omni_path();
        assert_eq!(
            f.roundtrip_ns(100, 1000),
            f.oneway_ns(100) + f.oneway_ns(1000)
        );
    }

    #[test]
    fn bulk_transfer_sane_duration() {
        // 128 KiB over FDR: ~19 µs wire + overheads; far under a ms.
        let f = Fabric::fdr_infiniband();
        assert!(f.oneway_ns(128 * 1024) < MS);
    }
}
