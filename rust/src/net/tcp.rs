//! Real-socket transport: [`TcpServer`] bridges incoming framed requests
//! onto a node's worker inbox, [`TcpTransport`] implements [`Transport`]
//! over per-peer pooled connections.
//!
//! # Server side
//!
//! `TcpServer::bind` returns the listener handle plus a [`NodeEndpoint`]
//! whose inbox is fed by the accept loop: one bridge thread per accepted
//! connection reads `[len][body]` frames ([`crate::net::wire`]), decodes
//! the request (paths interned per connection through a
//! [`wire::PathInterner`]), and forwards it as a [`Message`] whose
//! [`ReplySink`] encodes the response with the request's correlation id
//! and writes it back on the same connection **through a per-connection
//! [`wire::CoalescingWriter`]**: while other requests from the same
//! connection are still outstanding at the worker, small reply frames
//! (`Meta`, `NotFound`, acks) park in the coalescing buffer; the reply
//! that observes itself to be the last outstanding one flushes.  A lone
//! request's reply is therefore never delayed, and a pipelined fan-in
//! burst pays ~1 syscall per buffer instead of one per reply.  The node
//! worker (`FanStoreNode::spawn`) is byte-for-byte the same code that
//! serves the in-proc transport.
//!
//! # Client side
//!
//! Each peer gets a lazily-grown pool of connections (`pool_size` cap,
//! round-robin).  A connection pairs a write half (mutex-serialized,
//! coalescing frame writes — [`wire::CoalescingWriter`] batches
//! back-to-back small requests into one syscall per buffer and flushes
//! whenever the writer queue drains, while large payload frames write
//! through vectored with their `Arc<[u8]>` chunks uncopied) with one
//! demux reader thread that matches response frames to pending requests
//! by correlation id and completes their [`PendingReply`] channels.
//! Requests on one connection therefore pipeline: many callers can have
//! round trips in flight concurrently, replies resolve in whatever order
//! the worker produces them.
//!
//! # Shutdown ordering
//!
//! `shutdown_all` first sends a `Shutdown` request to every reachable
//! peer (the worker replies `Ok` and exits), then closes every pooled
//! socket.  Closing fails outstanding requests (their reply channels are
//! dropped, so `wait()` returns a transport error rather than hanging),
//! unblocks the demux readers (EOF), and the server-side bridge threads
//! exit when their socket closes or the worker inbox is gone.  The accept
//! loop itself stops when the [`TcpServer`] is dropped.

use std::collections::HashMap;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::error::{FanError, Result};
use crate::net::transport::{
    Message, NodeEndpoint, PendingReply, ReplySink, Request, Response, Transport,
};
use crate::net::wire::{self, CoalescingWriter};

/// Connections kept per peer before round-robining over them.
pub const DEFAULT_POOL_SIZE: usize = 2;

// ---------------------------------------------------------------------------
// Server side
// ---------------------------------------------------------------------------

/// Listener half of a TCP node: accepts connections and bridges their
/// framed requests onto the worker inbox returned from [`TcpServer::bind`].
/// Dropping it stops the accept loop (existing connections drain on their
/// own when the sockets or the worker go away).
pub struct TcpServer {
    node_id: u32,
    local_addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
}

impl TcpServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral loopback port)
    /// and return the server handle plus the node's worker endpoint.
    pub fn bind(node_id: u32, addr: impl ToSocketAddrs) -> Result<(TcpServer, NodeEndpoint)> {
        Self::bind_counted(node_id, addr, Arc::new(AtomicU64::new(0)))
    }

    /// [`TcpServer::bind`] with an externally owned reject counter,
    /// surfaced as `NodeStats::decode_rejects` when the coordinator wires
    /// the node's own counter through.  Every frame this server refuses —
    /// an oversize/corrupt length prefix or an undecodable body — bumps
    /// it; plain EOF and short reads (a peer hanging up) do not.  The
    /// decode-failure policy is per-connection: the offending bridge
    /// thread closes its own socket and the accept loop keeps serving
    /// everyone else.
    pub fn bind_counted(
        node_id: u32,
        addr: impl ToSocketAddrs,
        decode_rejects: Arc<AtomicU64>,
    ) -> Result<(TcpServer, NodeEndpoint)> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| FanError::Transport(format!("node {node_id} bind: {e}")))?;
        let local_addr = listener
            .local_addr()
            .map_err(|e| FanError::Transport(format!("node {node_id} local_addr: {e}")))?;
        let (inbox_tx, inbox_rx) = channel::<Message>();
        let stop = Arc::new(AtomicBool::new(false));
        let stop_flag = Arc::clone(&stop);
        let accept_thread = std::thread::Builder::new()
            .name(format!("fanstore-tcp-accept-{node_id}"))
            .spawn(move || {
                for stream in listener.incoming() {
                    if stop_flag.load(Ordering::SeqCst) {
                        break;
                    }
                    let stream = match stream {
                        Ok(s) => s,
                        Err(_) => {
                            // persistent accept errors (fd exhaustion)
                            // return immediately — back off instead of
                            // hot-spinning the accept thread
                            std::thread::sleep(std::time::Duration::from_millis(10));
                            continue;
                        }
                    };
                    let tx = inbox_tx.clone();
                    let rejects = Arc::clone(&decode_rejects);
                    let _ = std::thread::Builder::new()
                        .name(format!("fanstore-tcp-bridge-{node_id}"))
                        .spawn(move || bridge_connection(stream, tx, rejects));
                }
            })
            .map_err(|e| FanError::Transport(format!("spawn accept loop: {e}")))?;
        Ok((
            TcpServer {
                node_id,
                local_addr,
                stop,
                accept_thread: Some(accept_thread),
            },
            NodeEndpoint {
                node_id,
                inbox: inbox_rx,
            },
        ))
    }

    pub fn node_id(&self) -> u32 {
        self.node_id
    }

    /// The bound address (resolves the ephemeral port of `"...:0"` binds).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }
}

impl Drop for TcpServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // wake the accept loop so it observes the flag
        let _ = TcpStream::connect(self.local_addr);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
    }
}

/// Reply side of one accepted connection: a coalescing writer plus the
/// outstanding-request counter that implements the flush-when-served
/// rule.  `inflight` counts requests forwarded to the worker whose
/// replies have not yet been written back on this connection; a reply
/// that decrements it to zero knows no further reply is coming (the
/// worker serves its inbox FIFO on one thread) and flushes the buffer.
/// Pipelined bursts coalesce; a lone request's reply is written before
/// its `ReplySink` returns.
struct BridgeWriter {
    writer: Mutex<CoalescingWriter<TcpStream>>,
    inflight: AtomicUsize,
}

impl BridgeWriter {
    /// Write (or park) one correlated reply frame.  On error, kill the
    /// socket: parked frames of OTHER replies may be stranded in the
    /// buffer, and the peer's demux reader must fail every outstanding
    /// wait instead of hanging.
    fn write_reply(&self, frame: &wire::Frame) {
        let more_queued = self.inflight.fetch_sub(1, Ordering::AcqRel) > 1;
        let result = {
            let mut w = self.writer.lock().unwrap();
            w.write_frame(frame, more_queued)
        };
        if result.is_err() {
            self.kill();
        }
    }

    fn kill(&self) {
        if let Ok(w) = self.writer.lock() {
            let _ = w.get_ref().shutdown(Shutdown::Both);
        }
    }
}

/// Per-connection bridge: framed requests in, correlated (coalesced)
/// responses out.  A frame that fails to decode kills only this
/// connection (counted in `rejects`); the accept loop and every other
/// bridge keep running.
fn bridge_connection(stream: TcpStream, inbox: Sender<Message>, rejects: Arc<AtomicU64>) {
    let _ = stream.set_nodelay(true);
    let mut read_half = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let bw = Arc::new(BridgeWriter {
        writer: Mutex::new(CoalescingWriter::new(stream)),
        inflight: AtomicUsize::new(0),
    });
    // per-connection interner: an epoch's worth of repeated request paths
    // decodes into Arc clones of one allocation each
    let mut paths = wire::PathInterner::default();
    loop {
        // EOF / torn frame / corrupt body all close this connection; the
        // peer's pending requests fail over on its side.  Format errors
        // (a hostile or corrupt frame, as opposed to a peer hanging up)
        // are counted so operators can see garbage arriving.
        let body = match wire::read_frame(&mut read_half) {
            Ok(b) => b,
            Err(FanError::Format(_)) => {
                rejects.fetch_add(1, Ordering::Relaxed);
                break;
            }
            Err(_) => break,
        };
        let Ok((corr, from, req)) = wire::decode_request(&body, &mut paths) else {
            rejects.fetch_add(1, Ordering::Relaxed);
            break;
        };
        // account the request BEFORE forwarding: its reply must observe
        // every request forwarded ahead of it
        bw.inflight.fetch_add(1, Ordering::AcqRel);
        let w = Arc::clone(&bw);
        let reply = ReplySink::from_fn(move |resp| {
            let frame = wire::encode_response(corr, &resp);
            w.write_reply(&frame);
        });
        if inbox.send(Message { from, req, reply }).is_err() {
            // worker is gone (already shut down): un-account the request
            // (its sink will never run) and close the connection so the
            // client sees EOF instead of a silent hang
            bw.inflight.fetch_sub(1, Ordering::AcqRel);
            break;
        }
    }
    // drain anything still parked (replies that raced our exit), then close
    if let Ok(mut w) = bw.writer.lock() {
        let _ = w.flush();
        let _ = w.get_ref().shutdown(Shutdown::Both);
    }
}

// ---------------------------------------------------------------------------
// Client side
// ---------------------------------------------------------------------------

/// One pooled connection: mutex-serialized coalescing writes + a demux
/// reader thread resolving pending requests by correlation id.
struct TcpConn {
    writer: Mutex<CoalescingWriter<TcpStream>>,
    /// Writers queued on (or holding) the writer mutex right now.  A
    /// departing writer that observes nobody behind it flushes the
    /// coalescing buffer, so a frame is never parked while the connection
    /// is idle (the flush-when-drained rule).
    queued_writers: AtomicUsize,
    /// corr → reply channel.  `None` once the demux reader exited (every
    /// still-pending sender is dropped then, failing its `wait()`).
    pending: Mutex<Option<HashMap<u64, Sender<Response>>>>,
    next_corr: AtomicU64,
    dead: AtomicBool,
}

impl TcpConn {
    fn open(to: u32, addr: SocketAddr) -> Result<Arc<TcpConn>> {
        let stream = TcpStream::connect(addr)
            .map_err(|e| FanError::Transport(format!("connect node {to} at {addr}: {e}")))?;
        let _ = stream.set_nodelay(true);
        let read_half = stream
            .try_clone()
            .map_err(|e| FanError::Transport(format!("clone stream to node {to}: {e}")))?;
        let conn = Arc::new(TcpConn {
            writer: Mutex::new(CoalescingWriter::new(stream)),
            queued_writers: AtomicUsize::new(0),
            pending: Mutex::new(Some(HashMap::new())),
            next_corr: AtomicU64::new(1),
            dead: AtomicBool::new(false),
        });
        let demux = Arc::clone(&conn);
        std::thread::Builder::new()
            .name(format!("fanstore-tcp-demux-{to}"))
            .spawn(move || demux.reader_loop(read_half))
            .map_err(|e| FanError::Transport(format!("spawn demux reader: {e}")))?;
        Ok(conn)
    }

    /// Tear down the demux map: every still-pending sender is dropped, so
    /// each parked `PendingReply::wait` gets an immediate transport error
    /// instead of hanging, and the map's `None` state rejects new requests.
    /// Called wherever the connection dies: demux EOF, a failed write
    /// (frames of OTHER requests may be stranded in the coalescing
    /// buffer), and explicit close/eviction.
    fn fail_pending(&self) {
        self.dead.store(true, Ordering::SeqCst);
        if let Ok(mut p) = self.pending.lock() {
            *p = None;
        }
    }

    /// Demux loop: route each response frame to the request that owns its
    /// correlation id.  On connection teardown, fail everything pending.
    /// Batched-reply paths intern per connection, mirroring the server.
    fn reader_loop(&self, mut stream: TcpStream) {
        let mut paths = wire::PathInterner::default();
        loop {
            let body = match wire::read_frame(&mut stream) {
                Ok(b) => b,
                Err(_) => break,
            };
            let Ok((corr, resp)) = wire::decode_response(&body, &mut paths) else {
                break;
            };
            let tx = self
                .pending
                .lock()
                .map(|mut p| p.as_mut().and_then(|m| m.remove(&corr)))
                .unwrap_or(None);
            if let Some(tx) = tx {
                // receiver may have been dropped (abandoned PendingReply)
                let _ = tx.send(resp);
            }
        }
        // dropping the map drops every pending sender: their PendingReply
        // channels error out instead of hanging forever
        self.fail_pending();
        let _ = stream.shutdown(Shutdown::Both);
    }

    /// Register a pending slot, then write the framed request.
    fn request(&self, from: u32, to: u32, req: &Request) -> Result<PendingReply> {
        if self.dead.load(Ordering::SeqCst) {
            return Err(FanError::Transport(format!("node {to} connection closed")));
        }
        let corr = self.next_corr.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = channel();
        {
            let mut p = self.pending.lock().unwrap();
            match p.as_mut() {
                Some(m) => {
                    m.insert(corr, tx);
                }
                None => {
                    return Err(FanError::Transport(format!("node {to} connection closed")))
                }
            }
        }
        let frame = wire::encode_request(corr, from, req);
        // announce the write BEFORE taking the lock: the current lock
        // holder sees a follower and leaves its frames in the coalescing
        // buffer for us to carry (or flush) — back-to-back small requests
        // from many callers share one syscall per buffer
        self.queued_writers.fetch_add(1, Ordering::AcqRel);
        let write_result = {
            let mut w = self.writer.lock().unwrap();
            let more_queued = self.queued_writers.fetch_sub(1, Ordering::AcqRel) > 1;
            w.write_frame(&frame, more_queued)
        };
        if let Err(e) = write_result {
            // a failed coalesced write may strand OTHER requests' frames in
            // the buffer, and replies already in flight will never resolve:
            // drain the WHOLE demux map (every parked waiter errors now, not
            // when some far-off timeout fires) and kill the socket so the
            // demux reader exits too
            self.fail_pending();
            if let Ok(w) = self.writer.lock() {
                let _ = w.get_ref().shutdown(Shutdown::Both);
            }
            return Err(FanError::Transport(format!("send to node {to}: {e}")));
        }
        Ok(PendingReply::from_channel(to, rx))
    }

    fn close(&self) {
        // fail parked waiters synchronously — eviction of a Down peer's
        // sockets must not wait for the demux reader to notice the EOF
        self.fail_pending();
        if let Ok(mut w) = self.writer.lock() {
            let _ = w.flush();
            let _ = w.get_ref().shutdown(Shutdown::Both);
        }
    }
}

struct Peer {
    addr: SocketAddr,
    pool: Mutex<Vec<Arc<TcpConn>>>,
    rr: AtomicUsize,
}

impl Peer {
    /// Round-robin over live pooled connections, growing the pool up to
    /// `pool_size` and replacing dead connections on the way.
    fn conn(&self, to: u32, pool_size: usize) -> Result<Arc<TcpConn>> {
        {
            let mut pool = self.pool.lock().unwrap();
            pool.retain(|c| !c.dead.load(Ordering::SeqCst));
            if pool.len() >= pool_size {
                let i = self.rr.fetch_add(1, Ordering::Relaxed) % pool.len();
                return Ok(Arc::clone(&pool[i]));
            }
        }
        // dial OUTSIDE the pool lock: a blackholed peer's SYN timeout must
        // not stall senders that could round-robin onto a healthy pooled
        // connection (racing dials may transiently overshoot `pool_size`
        // by a connection or two — harmless, they drain by round-robin)
        let conn = TcpConn::open(to, self.addr)?;
        self.pool.lock().unwrap().push(Arc::clone(&conn));
        Ok(conn)
    }

    fn close_all(&self) {
        let conns: Vec<Arc<TcpConn>> = {
            let mut pool = self.pool.lock().unwrap();
            pool.drain(..).collect()
        };
        for c in conns {
            c.close();
        }
    }
}

/// [`Transport`] over real sockets: peer `i` of the address list is node
/// `i`.  Connections are opened lazily, pooled per peer, and demuxed by
/// correlation id, so one transport value serves any number of concurrent
/// clients (exactly like the in-proc sender bundle).
pub struct TcpTransport {
    peers: Vec<Peer>,
    pool_size: usize,
    /// Per-call reply deadline (`--call-timeout-ms`); `None` waits forever.
    call_timeout: Option<Duration>,
}

impl TcpTransport {
    /// Address the cluster: `addrs[i]` is node `i`'s listener.  No sockets
    /// are opened until the first send to each peer.
    pub fn connect(addrs: &[SocketAddr]) -> Result<TcpTransport> {
        Self::connect_with(addrs, DEFAULT_POOL_SIZE, None)
    }

    /// [`TcpTransport::connect`] with an explicit per-peer pool size.
    pub fn connect_pooled(addrs: &[SocketAddr], pool_size: usize) -> Result<TcpTransport> {
        Self::connect_with(addrs, pool_size, None)
    }

    /// Full-knob constructor: pool size plus the bounded per-call reply
    /// wait every `call` through this transport honors.
    pub fn connect_with(
        addrs: &[SocketAddr],
        pool_size: usize,
        call_timeout: Option<Duration>,
    ) -> Result<TcpTransport> {
        if addrs.is_empty() {
            return Err(FanError::Transport("empty peer address list".into()));
        }
        Ok(TcpTransport {
            peers: addrs
                .iter()
                .map(|&addr| Peer {
                    addr,
                    pool: Mutex::new(Vec::new()),
                    rr: AtomicUsize::new(0),
                })
                .collect(),
            pool_size: pool_size.max(1),
            call_timeout,
        })
    }

    fn peer(&self, to: u32) -> Result<&Peer> {
        self.peers
            .get(to as usize)
            .ok_or_else(|| FanError::Transport(format!("no such node {to}")))
    }

    /// Close every pooled connection (failing outstanding requests and
    /// releasing the demux readers).  Idempotent.
    pub fn disconnect(&self) {
        for peer in &self.peers {
            peer.close_all();
        }
    }
}

impl Transport for TcpTransport {
    fn node_count(&self) -> u32 {
        self.peers.len() as u32
    }

    fn send(&self, from: u32, to: u32, req: Request) -> Result<PendingReply> {
        let peer = self.peer(to)?;
        // one retry through a fresh connection: the pooled socket may have
        // died since its last use (peer restart, idle teardown)
        match peer.conn(to, self.pool_size)?.request(from, to, &req) {
            Ok(pending) => Ok(pending),
            Err(_) => peer.conn(to, self.pool_size)?.request(from, to, &req),
        }
    }

    fn shutdown_all(&self) {
        // ask every worker to exit (reply ignored), then drop the sockets
        for to in 0..self.peers.len() as u32 {
            let _ = self.send(u32::MAX, to, Request::Shutdown);
        }
        self.disconnect();
    }

    /// Drop `node`'s pooled sockets (failing its parked waiters now).  The
    /// health layer calls this on the transition into Down so no reader
    /// keeps queueing onto a dead peer's demux; a later send re-dials.
    fn evict(&self, node: u32) {
        if let Ok(peer) = self.peer(node) {
            peer.close_all();
        }
    }

    fn call_timeout(&self) -> Option<Duration> {
        self.call_timeout
    }
}

impl Drop for TcpTransport {
    fn drop(&mut self) {
        self.disconnect();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::transport::FileFetch;
    use std::thread;

    /// Echo worker identical in shape to the in-proc transport tests.
    fn spawn_echo(ep: NodeEndpoint) -> thread::JoinHandle<u32> {
        thread::spawn(move || {
            let mut served = 0;
            while let Ok(msg) = ep.inbox.recv() {
                match msg.req {
                    Request::Shutdown => {
                        msg.reply.send(Response::Ok);
                        break;
                    }
                    Request::ReadFile { path } => {
                        served += 1;
                        msg.reply.send(Response::FileData {
                            stored: path.as_bytes().to_vec().into(),
                        });
                    }
                    Request::ReadFiles { paths } => {
                        served += 1;
                        let files = paths
                            .into_iter()
                            .map(|p| {
                                let fetch = if p.contains("missing") {
                                    FileFetch::NotFound
                                } else {
                                    FileFetch::Data {
                                        stored: p.as_bytes().to_vec().into(),
                                    }
                                };
                                (p, fetch)
                            })
                            .collect();
                        msg.reply.send(Response::FilesData(files));
                    }
                    _ => {
                        msg.reply.send(Response::Ok);
                    }
                }
            }
            served
        })
    }

    fn loopback(n: u32) -> (TcpTransport, Vec<TcpServer>, Vec<thread::JoinHandle<u32>>) {
        let mut servers = Vec::new();
        let mut addrs = Vec::new();
        let mut workers = Vec::new();
        for id in 0..n {
            let (srv, ep) = TcpServer::bind(id, "127.0.0.1:0").unwrap();
            addrs.push(srv.local_addr());
            servers.push(srv);
            workers.push(spawn_echo(ep));
        }
        (TcpTransport::connect(&addrs).unwrap(), servers, workers)
    }

    #[test]
    fn tcp_roundtrip_between_nodes() {
        let (tp, servers, workers) = loopback(3);
        let resp = tp
            .call(0, 2, Request::ReadFile { path: "/x/y".into() })
            .unwrap();
        let data = resp.into_file_data().unwrap();
        assert_eq!(&data[..], &b"/x/y"[..]);
        tp.shutdown_all();
        let served: u32 = workers.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(served, 1);
        drop(servers);
    }

    #[test]
    fn tcp_batched_roundtrip_and_overlapped_sends() {
        let (tp, servers, workers) = loopback(4);
        // batched: one request, per-file outcomes in order
        let files = tp
            .call(
                0,
                1,
                Request::ReadFiles {
                    paths: vec!["/a".into(), "/missing/x".into(), "/b".into()],
                },
            )
            .unwrap()
            .into_files_data()
            .unwrap();
        assert_eq!(files.len(), 3);
        assert!(files[0].1.is_data());
        assert!(matches!(files[1].1, FileFetch::NotFound));
        // overlapped gather across three peers
        let pending: Vec<PendingReply> = (1..4)
            .map(|to| {
                tp.send(0, to, Request::ReadFile { path: format!("/p{to}").into() })
                    .unwrap()
            })
            .collect();
        for (i, p) in pending.into_iter().enumerate() {
            let data = p.wait().unwrap().into_file_data().unwrap();
            assert_eq!(&data[..], format!("/p{}", i + 1).as_bytes());
        }
        tp.shutdown_all();
        let served: u32 = workers.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(served, 4);
        drop(servers);
    }

    #[test]
    fn tcp_many_concurrent_callers_pipeline_on_pooled_connections() {
        let (tp, servers, workers) = loopback(2);
        let tp = Arc::new(tp);
        let mut callers = Vec::new();
        for i in 0..6u32 {
            let tp = Arc::clone(&tp);
            callers.push(thread::spawn(move || {
                for j in 0..40u32 {
                    let r = tp
                        .call(0, 1, Request::ReadFile {
                            path: format!("/f/{i}_{j}").into(),
                        })
                        .unwrap();
                    let d = r.into_file_data().unwrap();
                    assert_eq!(&d[..], format!("/f/{i}_{j}").as_bytes());
                }
            }));
        }
        for c in callers {
            c.join().unwrap();
        }
        tp.shutdown_all();
        let served: u32 = workers.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(served, 240);
        drop(servers);
    }

    #[test]
    fn tcp_dead_peer_errors_instead_of_hanging() {
        // no listener at this address: send must fail, not hang
        let dead: SocketAddr = "127.0.0.1:1".parse().unwrap();
        let tp = TcpTransport::connect(&[dead]).unwrap();
        let err = tp
            .call(0, 0, Request::ReadFile { path: "/x".into() })
            .unwrap_err();
        assert!(matches!(err, FanError::Transport(_)), "{err}");
        // a worker that dies mid-conversation fails pending requests
        let (srv, ep) = TcpServer::bind(0, "127.0.0.1:0").unwrap();
        let addr = srv.local_addr();
        drop(ep); // worker never runs: inbox receiver is gone
        let tp = TcpTransport::connect(&[addr]).unwrap();
        let r = tp.call(0, 0, Request::ReadFile { path: "/y".into() });
        assert!(r.is_err(), "dropped worker must surface an error");
        drop(srv);
    }

    #[test]
    fn tcp_unknown_node_is_error() {
        let (tp, servers, workers) = loopback(1);
        assert!(tp.call(0, 9, Request::Shutdown).is_err());
        tp.shutdown_all();
        for w in workers {
            w.join().unwrap();
        }
        drop(servers);
    }

    #[test]
    fn garbage_bytes_kill_only_their_own_connection() {
        use std::io::{Read as _, Write as _};
        // a live server with an owned reject counter; a healthy client
        // talks to it before, during, and after hostile connections feed
        // it garbage — only the garbage connections may die
        let rejects = Arc::new(AtomicU64::new(0));
        let (srv, ep) = TcpServer::bind_counted(0, "127.0.0.1:0", Arc::clone(&rejects)).unwrap();
        let worker = spawn_echo(ep);
        let tp = TcpTransport::connect(&[srv.local_addr()]).unwrap();
        let d = tp
            .call(0, 0, Request::ReadFile { path: "/ok".into() })
            .unwrap()
            .into_file_data()
            .unwrap();
        assert_eq!(&d[..], b"/ok");

        // hostile frame #1: valid length prefix, undecodable body
        let mut framed_garbage = Vec::new();
        framed_garbage.extend_from_slice(&8u32.to_le_bytes());
        framed_garbage.extend_from_slice(&[0xEE; 8]);
        // hostile frame #2: length prefix beyond MAX_FRAME
        let oversize_prefix = u32::MAX.to_le_bytes().to_vec();
        for garbage in [framed_garbage, oversize_prefix] {
            let mut s = TcpStream::connect(srv.local_addr()).unwrap();
            s.write_all(&garbage).unwrap();
            let _ = s.shutdown(Shutdown::Write);
            // the bridge must close THIS connection: read to EOF
            let mut buf = [0u8; 64];
            loop {
                match s.read(&mut buf) {
                    Ok(0) | Err(_) => break,
                    Ok(_) => {}
                }
            }
        }

        // the accept loop survived: the old connection still works and a
        // brand-new one is served too
        let d = tp
            .call(0, 0, Request::ReadFile { path: "/still".into() })
            .unwrap()
            .into_file_data()
            .unwrap();
        assert_eq!(&d[..], b"/still");
        let tp2 = TcpTransport::connect(&[srv.local_addr()]).unwrap();
        let d = tp2
            .call(0, 0, Request::ReadFile { path: "/fresh".into() })
            .unwrap()
            .into_file_data()
            .unwrap();
        assert_eq!(&d[..], b"/fresh");

        // both rejects are counted (bounded wait: the bridge bumps the
        // counter just before closing the socket we EOF'd on)
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while rejects.load(Ordering::SeqCst) < 2 && std::time::Instant::now() < deadline {
            thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(rejects.load(Ordering::SeqCst), 2, "both garbage frames counted");

        tp.shutdown_all();
        tp2.disconnect();
        worker.join().unwrap();
        drop(srv);
    }

    #[test]
    fn pipelined_replies_coalesce_without_parking() {
        // one pooled connection (pool_size = 1): overlapped requests travel
        // on a single socket, so their replies hit the bridge's coalescing
        // writer back-to-back.  Every reply must still arrive — the last
        // outstanding reply flushes the parked batch — and a lone request
        // after each burst must not be delayed behind an idle buffer.
        let (srv, ep) = TcpServer::bind(0, "127.0.0.1:0").unwrap();
        let worker = spawn_echo(ep);
        let tp = TcpTransport::connect_pooled(&[srv.local_addr()], 1).unwrap();
        for round in 0..8u32 {
            let pending: Vec<PendingReply> = (0..32u32)
                .map(|i| {
                    tp.send(0, 0, Request::ReadFile {
                        path: format!("/r{round}/f{i}").into(),
                    })
                    .unwrap()
                })
                .collect();
            for (i, pnd) in pending.into_iter().enumerate() {
                let d = pnd.wait().unwrap().into_file_data().unwrap();
                assert_eq!(&d[..], format!("/r{round}/f{i}").as_bytes());
            }
            // lone request after the burst: flush-when-served keeps it prompt
            let d = tp
                .call(0, 0, Request::ReadFile { path: "/lone".into() })
                .unwrap()
                .into_file_data()
                .unwrap();
            assert_eq!(&d[..], b"/lone");
        }
        tp.shutdown_all();
        worker.join().unwrap();
        drop(srv);
    }

    #[test]
    fn write_error_drains_every_parked_waiter() {
        // a sink peer: accepts, swallows request bytes, never replies, and
        // keeps its end open — so the client's demux reader sees no EOF and
        // ONLY the failed-write teardown can free a parked waiter
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let (done_tx, done_rx) = channel::<()>();
        let sink = thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let mut buf = [0u8; 4096];
            loop {
                match std::io::Read::read(&mut s, &mut buf) {
                    Ok(0) | Err(_) => break,
                    Ok(_) => {}
                }
            }
            let _ = done_rx.recv();
            drop(s);
        });
        let tp = TcpTransport::connect_pooled(&[addr], 1).unwrap();
        let parked = tp
            .send(0, 0, Request::ReadFile { path: "/a".into() })
            .unwrap();
        // force the NEXT write on this connection to fail: Rust ignores
        // SIGPIPE, so writing after SHUT_WR returns BrokenPipe instead of
        // killing the process
        let conn = Arc::clone(&tp.peers[0].pool.lock().unwrap()[0]);
        let _ = conn.writer.lock().unwrap().get_ref().shutdown(Shutdown::Write);
        let b = conn.request(0, 0, &Request::ReadFile { path: "/b".into() });
        assert!(b.is_err(), "write after SHUT_WR must error");
        // the failed write drained the whole demux map: request A fails NOW,
        // not when some far-off timeout fires
        let t0 = std::time::Instant::now();
        let err = parked.wait_timeout(Duration::from_secs(10)).unwrap_err();
        assert!(matches!(err, FanError::Transport(_)), "{err}");
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "parked waiter must fail at teardown: {:?}",
            t0.elapsed()
        );
        done_tx.send(()).unwrap();
        sink.join().unwrap();
    }

    #[test]
    fn call_timeout_bounds_a_silent_peer() {
        // peer accepts but never replies: `call` must return in ~timeout
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let (done_tx, done_rx) = channel::<()>();
        let sink = thread::spawn(move || {
            let (s, _) = listener.accept().unwrap();
            let _ = done_rx.recv();
            drop(s);
        });
        let tp =
            TcpTransport::connect_with(&[addr], 1, Some(Duration::from_millis(100))).unwrap();
        let t0 = std::time::Instant::now();
        let err = tp
            .call(0, 0, Request::ReadFile { path: "/t".into() })
            .unwrap_err();
        assert!(matches!(err, FanError::Transport(_)), "{err}");
        let dt = t0.elapsed();
        assert!(dt >= Duration::from_millis(100), "early return: {dt:?}");
        assert!(dt < Duration::from_secs(5), "deadline not honored: {dt:?}");
        done_tx.send(()).unwrap();
        sink.join().unwrap();
    }

    #[test]
    fn evict_closes_the_pool_and_a_later_send_redials() {
        let (tp, servers, workers) = loopback(2);
        let d = tp
            .call(0, 1, Request::ReadFile { path: "/warm".into() })
            .unwrap()
            .into_file_data()
            .unwrap();
        assert_eq!(&d[..], b"/warm");
        let pooled = Arc::clone(&tp.peers[1].pool.lock().unwrap()[0]);
        tp.evict(1);
        assert!(pooled.dead.load(Ordering::SeqCst), "evicted conn must die");
        assert!(tp.peers[1].pool.lock().unwrap().is_empty(), "pool drained");
        // the peer itself is alive: the next call re-dials transparently
        let d = tp
            .call(0, 1, Request::ReadFile { path: "/again".into() })
            .unwrap()
            .into_file_data()
            .unwrap();
        assert_eq!(&d[..], b"/again");
        tp.shutdown_all();
        for w in workers {
            w.join().unwrap();
        }
        drop(servers);
    }

    #[test]
    fn compressed_payload_survives_the_socket() {
        use crate::compress::Codec;
        use crate::storage::payload::Payload;

        // server compresses once; the socket must carry the stored form and
        // the frame must preserve codec + raw_len for the consuming node
        let raw: Vec<u8> = (0..32 * 1024u32).map(|i| (i % 97) as u8).collect();
        let codec = Codec::Lzss(5);
        let packed = codec.compress(&raw).expect("synthetic data compresses");
        assert!(packed.len() * 2 < raw.len());
        let stored = Payload::compressed(codec, raw.len() as u64, packed.into());

        let (srv, ep) = TcpServer::bind(0, "127.0.0.1:0").unwrap();
        let worker = {
            let stored = stored.clone();
            thread::spawn(move || {
                while let Ok(msg) = ep.inbox.recv() {
                    match msg.req {
                        Request::Shutdown => {
                            msg.reply.send(Response::Ok);
                            break;
                        }
                        _ => msg.reply.send(Response::FileData {
                            stored: stored.clone(),
                        }),
                    }
                }
            })
        };
        let tp = TcpTransport::connect(&[srv.local_addr()]).unwrap();
        let got = tp
            .call(0, 0, Request::ReadFile { path: "/c".into() })
            .unwrap()
            .into_file_data()
            .unwrap();
        assert_eq!(got.codec(), codec);
        assert_eq!(got.raw_len(), raw.len() as u64);
        assert!(
            got.len() * 2 < raw.len(),
            "wire must ship compressed bytes, not the decoded file"
        );
        let back = got.codec().decompress(&got, raw.len()).unwrap();
        assert_eq!(back, raw);
        tp.shutdown_all();
        worker.join().unwrap();
        drop(srv);
    }
}
