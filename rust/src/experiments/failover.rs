//! Failover drill (PR 7): kill a node mid-sweep and prove the read path
//! survives on the replicas.
//!
//! One reader (node 0) sweeps the whole namespace twice on identically
//! configured clusters — once healthy, once with node 1 killed halfway
//! through the sweep.  The topology (3 nodes, 3 partitions, replication 2)
//! makes node 1 the preferred holder of exactly the one partition node 0
//! must fetch remotely, so the kill lands on the hot remote path.  With a
//! surviving replica for every partition the chaos sweep must return
//! byte-identical data (same FNV-1a digest as the healthy sweep) while the
//! `failovers`/`retries`/`peers_marked_down` counters light up and
//! `degraded_reads` stays zero.

use crate::config::{ClusterConfig, TransportKind};
use crate::coordinator::Cluster;
use crate::error::Result;
use crate::experiments::report::{f1, shape_check, Table};
use crate::node::NodeStats;
use crate::partition::builder::InputFile;
use crate::util::prng::Prng;
use crate::vfs::Vfs;

/// One fabric's healthy-vs-chaos pair over the identical workload.
#[derive(Clone, Debug)]
pub struct FailoverRun {
    pub kind: TransportKind,
    pub files: u64,
    pub bytes: u64,
    pub healthy_digest: u64,
    pub chaos_digest: u64,
    pub healthy_seconds: f64,
    pub chaos_seconds: f64,
    /// Reader-node (node 0) stats of the chaos sweep.
    pub chaos_stats: NodeStats,
}

impl FailoverRun {
    pub fn survived(&self) -> bool {
        self.chaos_digest == self.healthy_digest
            && self.chaos_stats.failovers > 0
            && self.chaos_stats.degraded_reads == 0
    }
}

fn fnv1a(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01B3);
    }
    h
}

fn drill_config(kind: TransportKind) -> ClusterConfig {
    ClusterConfig {
        nodes: 3,
        partitions: 3,
        replication: 2,
        transport: kind,
        ..Default::default()
    }
}

fn drill_dataset(file_count: usize, file_size: usize) -> Vec<InputFile> {
    let mut rng = Prng::new(0xFA11);
    (0..file_count)
        .map(|i| {
            let mut data = vec![0u8; file_size];
            rng.fill_bytes(&mut data);
            InputFile {
                path: format!("train/f{i:05}"),
                data,
            }
        })
        .collect()
}

/// Run the drill on a fresh cluster per fabric.  `file_count` files of
/// `file_size` bytes; the kill lands after half the (shuffled) sweep.
pub fn run_failover(
    kinds: &[TransportKind],
    file_count: usize,
    file_size: usize,
) -> Result<Vec<FailoverRun>> {
    let files = drill_dataset(file_count, file_size);
    let paths: Vec<String> = files
        .iter()
        .map(|f| format!("/fanstore/user/{}", f.path))
        .collect();
    // deterministic shuffled order: remote reads of the doomed holder's
    // partition land on both sides of the kill
    let mut order: Vec<u32> = (0..file_count as u32).collect();
    Prng::new(0x5EED).shuffle(&mut order);

    let mut out = Vec::new();
    for &kind in kinds {
        // healthy sweep
        let cluster = Cluster::launch(&files, drill_config(kind))?;
        let mut vfs = cluster.client(0);
        let t0 = std::time::Instant::now();
        let mut healthy_digest = 0xCBF2_9CE4_8422_2325u64;
        let mut bytes = 0u64;
        for &i in &order {
            let data = vfs.read_all(&paths[i as usize])?;
            bytes += data.len() as u64;
            healthy_digest = fnv1a(healthy_digest, &data);
        }
        let healthy_seconds = t0.elapsed().as_secs_f64();
        drop(vfs);
        cluster.shutdown();

        // chaos sweep: same workload, node 1 dies at the halfway mark
        let mut cluster = Cluster::launch(&files, drill_config(kind))?;
        let mut vfs = cluster.client(0);
        let t0 = std::time::Instant::now();
        let mut chaos_digest = 0xCBF2_9CE4_8422_2325u64;
        for (k, &i) in order.iter().enumerate() {
            if k == order.len() / 2 {
                cluster.kill_node(1);
            }
            let data = vfs.read_all(&paths[i as usize])?;
            chaos_digest = fnv1a(chaos_digest, &data);
        }
        let chaos_seconds = t0.elapsed().as_secs_f64();
        drop(vfs);
        let report = cluster.shutdown();
        out.push(FailoverRun {
            kind,
            files: file_count as u64,
            bytes,
            healthy_digest,
            chaos_digest,
            healthy_seconds,
            chaos_seconds,
            chaos_stats: report.per_node[0],
        });
    }
    Ok(out)
}

pub fn report_failover(runs: &[FailoverRun]) {
    let mut t = Table::new(
        "Failover drill — node 1 killed mid-sweep (3 nodes, r=2)",
        &[
            "fabric",
            "files",
            "healthy MB/s",
            "chaos MB/s",
            "digest match",
            "failovers",
            "retries",
            "marked down",
            "degraded",
        ],
    );
    for r in runs {
        t.row(&[
            r.kind.name().to_string(),
            r.files.to_string(),
            f1(r.bytes as f64 / r.healthy_seconds.max(1e-9) / 1e6),
            f1(r.bytes as f64 / r.chaos_seconds.max(1e-9) / 1e6),
            if r.chaos_digest == r.healthy_digest {
                "yes".into()
            } else {
                "NO".into()
            },
            r.chaos_stats.failovers.to_string(),
            r.chaos_stats.retries.to_string(),
            r.chaos_stats.peers_marked_down.to_string(),
            r.chaos_stats.degraded_reads.to_string(),
        ]);
    }
    t.print();
    for r in runs {
        shape_check(
            &format!("{}: chaos sweep byte-identical with failovers>0", r.kind.name()),
            if r.survived() { 1.0 } else { 0.0 },
            0.5,
            1.5,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn drill_survives_the_kill_on_the_inproc_fabric() {
        let runs = run_failover(&[TransportKind::InProc], 48, 512).unwrap();
        let r = &runs[0];
        assert_eq!(r.chaos_digest, r.healthy_digest, "reads must stay byte-identical");
        assert!(r.chaos_stats.failovers > 0, "{:?}", r.chaos_stats);
        assert_eq!(r.chaos_stats.degraded_reads, 0, "{:?}", r.chaos_stats);
        assert!(r.survived());
    }
}
