//! Virtual-time I/O backends: FanStore and the three baselines of §6.4,
//! evaluated on the DES-lite substrate.
//!
//! Device parameters come from [`crate::storage::models`] and
//! [`crate::net::fabric`]; the FanStore logic (placement, locality,
//! compressed transfer + reader-side decompression, interception overhead)
//! is the same logic the real in-proc stack uses.

use std::collections::BinaryHeap;

use crate::metadata::placement::Placement;
use crate::net::fabric::{Fabric, REQUEST_BYTES};
use crate::sim::clock::{transfer_ns, SimNs, US};
use crate::sim::Resource;
use crate::storage::models::{FuseModel, SharedFsModel, SsdModel};
use crate::workload::bench::BenchResult;

/// One simulated file: raw size + stored size (≠ raw when compressed) and
/// the partition it was packed into.
#[derive(Clone, Copy, Debug)]
pub struct SimFile {
    pub raw: u64,
    pub stored: u64,
    pub partition: u32,
}

/// A dataset for the simulator.
#[derive(Clone, Debug)]
pub struct SimDataset {
    pub files: Vec<SimFile>,
}

impl SimDataset {
    /// Uniform file size, round-robin partitions (the §6.2 benchmark).
    pub fn uniform(count: u64, size: u64, partitions: u32, ratio: f64) -> Self {
        let stored = ((size as f64 / ratio.max(1.0)) as u64).max(1);
        SimDataset {
            files: (0..count)
                .map(|i| SimFile {
                    raw: size,
                    stored,
                    partition: (i % partitions as u64) as u32,
                })
                .collect(),
        }
    }

    /// From a drawn size list.
    pub fn from_sizes(sizes: &[u64], partitions: u32, ratio: f64) -> Self {
        SimDataset {
            files: sizes
                .iter()
                .enumerate()
                .map(|(i, &s)| SimFile {
                    raw: s,
                    stored: ((s as f64 / ratio.max(1.0)) as u64).max(1),
                    partition: (i % partitions as usize) as u32,
                })
                .collect(),
        }
    }

    pub fn total_raw(&self) -> u64 {
        self.files.iter().map(|f| f.raw).sum()
    }
}

/// A backend that can price one whole-file read in virtual time.
pub trait IoSim {
    /// Read `file` from `node`, arriving at `now`; returns completion time.
    fn read(&mut self, now: SimNs, node: u32, file: &SimFile) -> SimNs;
    /// Price of the startup metadata traversal (per process, §3.3).
    fn metadata_scan(&mut self, now: SimNs, node: u32, n_entries: u64) -> SimNs;
    fn name(&self) -> &'static str;
}

/// FanStore: interception + local SSD or remote round trip + decompression.
pub struct FanStoreSim {
    pub placement: Placement,
    pub fabric: Fabric,
    pub ssd_model: SsdModel,
    /// Per-node SSD / NIC-tx / NIC-rx FIFO timelines.
    ssd: Vec<Resource>,
    nic_tx: Vec<Resource>,
    /// Reader-side LZSS decode rate (bytes of *raw* output per second);
    /// calibrated against the real codec by benches/hotpath.rs.
    pub decompress_bw: u64,
    /// Per-file decode setup (output-buffer allocation + first-touch page
    /// faults + cold caches) — why small compressed files lose on one node
    /// (Fig 11: ~50 % for 128 KB).
    pub decompress_setup_ns: SimNs,
    /// User-space interception dispatch cost (§5.5: nanoseconds, the whole
    /// point vs FUSE's microseconds).
    pub intercept_ns: SimNs,
}

impl FanStoreSim {
    pub fn new(nodes: u32, partitions: u32, replication: u32, fabric: Fabric) -> Self {
        let ssd_model = SsdModel::sata_2018();
        FanStoreSim {
            placement: Placement::new(nodes, partitions, replication),
            fabric,
            ssd_model,
            ssd: (0..nodes).map(|_| Resource::new(ssd_model.lanes)).collect(),
            nic_tx: (0..nodes).map(|_| Resource::new(1)).collect(),
            // calibrated from `cargo bench --bench hotpath` on this host
            // after the §Perf pass: LZSS decode of srgan-like data at
            // 1.5 GB/s raw-output rate + per-file setup
            decompress_bw: 1_500_000_000,
            decompress_setup_ns: 250 * US,
            intercept_ns: 200, // ~0.2 µs dispatch, §6.4's "little overhead"
        }
    }

    fn decompress_ns(&self, file: &SimFile) -> SimNs {
        if file.stored == file.raw {
            0
        } else {
            self.decompress_setup_ns + transfer_ns(file.raw, self.decompress_bw)
        }
    }
}

impl IoSim for FanStoreSim {
    fn read(&mut self, now: SimNs, node: u32, file: &SimFile) -> SimNs {
        let now = now + self.intercept_ns; // open()+read()+close() dispatch
        let holder = self.placement.choose_holder(file.partition, node);
        if holder == node {
            // local: the node's FanStore worker pulls the stored bytes from
            // SSD and decompresses *before returning content* (§5.4) — the
            // read+decode pipeline occupies the local I/O path end to end,
            // which is why small compressed files lose on one node (Fig 11)
            let service = self.ssd_model.read_service(file.stored) + self.decompress_ns(file);
            self.ssd[node as usize].serve(now, service)
        } else {
            // remote round trip (paper §5.4): request out ...
            let t1 = self.nic_tx[node as usize].serve(now, self.fabric.tx_service(REQUEST_BYTES));
            let t2 = t1 + self.fabric.latency_ns;
            // ... holder reads its SSD ...
            let t3 = self.ssd[holder as usize]
                .serve(t2, self.ssd_model.read_service(file.stored));
            // ... reply serializes on the holder's NIC.  (Reader-side rx
            // is NOT a FIFO resource here: arrivals from different holders
            // reach the reader out of order, and a FIFO timeline would act
            // as a false serializer propagating the slowest holder's delay
            // to every read.  Reader rx load is ≤4 concurrent streams and
            // the fat tree is non-blocking, so sender-side serialization is
            // the binding constraint — §6.1.)
            let svc = self.fabric.tx_service(file.stored);
            let t_tx = self.nic_tx[holder as usize].serve(t3, svc);
            // decode happens on the *requesting* process's thread pool —
            // overlapped across the reader's 4 I/O threads, so compression
            // wins once traffic is remote (the Fig 11 crossover)
            t_tx + self.fabric.latency_ns + self.decompress_ns(file)
        }
    }

    fn metadata_scan(&mut self, now: SimNs, _node: u32, n_entries: u64) -> SimNs {
        // replicated RAM hashtable: ~80ns per entry, no device involved
        now + n_entries * 80
    }

    fn name(&self) -> &'static str {
        "FanStore"
    }
}

/// Raw local SSD through the kernel (the Fig 3 upper bound).
pub struct SsdSim {
    model: SsdModel,
    ssd: Vec<Resource>,
    /// VFS syscall cost (kernel path, no FUSE).
    syscall_ns: SimNs,
}

impl SsdSim {
    pub fn new(nodes: u32) -> Self {
        let model = SsdModel::sata_2018();
        SsdSim {
            model,
            ssd: (0..nodes).map(|_| Resource::new(model.lanes)).collect(),
            syscall_ns: 2 * US, // open+read+close through the kernel + page cache miss
        }
    }
}

impl IoSim for SsdSim {
    fn read(&mut self, now: SimNs, node: u32, file: &SimFile) -> SimNs {
        // the SSD baseline stores *raw* files (no partitions, no codec)
        self.ssd[node as usize].serve(now + self.syscall_ns, self.model.read_service(file.raw))
    }

    fn metadata_scan(&mut self, now: SimNs, _node: u32, n_entries: u64) -> SimNs {
        // local ext4: dentry walk ~3µs per entry cold-ish
        now + n_entries * 3 * US
    }

    fn name(&self) -> &'static str {
        "SSD"
    }
}

/// SSD behind FUSE (Fig 3's SSD-fuse).
pub struct FuseSim {
    model: FuseModel,
    ssd: Vec<Resource>,
}

impl FuseSim {
    pub fn new(nodes: u32) -> Self {
        let model = FuseModel::default_2018();
        FuseSim {
            model,
            ssd: (0..nodes).map(|_| Resource::new(model.ssd.lanes)).collect(),
        }
    }
}

impl IoSim for FuseSim {
    fn read(&mut self, now: SimNs, node: u32, file: &SimFile) -> SimNs {
        self.ssd[node as usize].serve(now, self.model.read_service(file.raw))
    }

    fn metadata_scan(&mut self, now: SimNs, _node: u32, n_entries: u64) -> SimNs {
        // readdir batches ~64 dirents per crossing; each entry still walks
        // the backing fs (~3µs)
        let crossings = n_entries.div_ceil(64);
        now + crossings * self.model.metadata_service() + n_entries * 3 * US
    }

    fn name(&self) -> &'static str {
        "SSD-fuse"
    }
}

/// Lustre-class shared file system (Fig 3's SFS).
pub struct SharedFsSim {
    model: SharedFsModel,
    /// single MDS, shared by the whole cluster (§3.3)
    mds: Resource,
    /// shared OST pool
    ost: Resource,
    /// per-client link
    client: Vec<Resource>,
}

impl SharedFsSim {
    pub fn new(nodes: u32) -> Self {
        let model = SharedFsModel::lustre_2018();
        SharedFsSim {
            model,
            mds: Resource::new(1),
            ost: Resource::new(model.ost_lanes),
            client: (0..nodes).map(|_| Resource::new(1)).collect(),
        }
    }
}

impl IoSim for SharedFsSim {
    fn read(&mut self, now: SimNs, node: u32, file: &SimFile) -> SimNs {
        // open: the full metadata RPC chain through the single MDS
        let t1 = self.mds.serve(now, self.model.open_service()) + self.model.rpc_ns;
        // data: shared OSTs, then the client link
        let t2 = self.ost.serve(t1, self.model.ost_service(file.raw));
        self.client[node as usize].serve(t2, self.model.client_service(file.raw))
    }

    fn metadata_scan(&mut self, now: SimNs, _node: u32, n_entries: u64) -> SimNs {
        // every stat()/readdir batch is an MDS op; batch ~64 entries/RPC
        let rpcs = n_entries.div_ceil(64).max(1);
        let mut t = now;
        for _ in 0..rpcs {
            t = self.mds.serve(t, self.model.mds_service()) + self.model.rpc_ns;
        }
        t
    }

    fn name(&self) -> &'static str {
        "SFS"
    }
}

/// Run the §6.2 benchmark on a backend: `nodes` nodes × `threads` I/O
/// threads each; every node performs `count` whole-file reads (the paper's
/// "each node reads all files in the directory") in uniform-random order.
///
/// Random order matters: nodes sweeping the directory in the *same* order
/// would convoy on one partition holder at a time, which neither real
/// training (§3.4: uniform random access) nor the paper's benchmark does.
/// Uniform sampling is statistically identical load to a per-node random
/// permutation and needs no O(nodes×count) order storage at 512-node scale.
pub fn run_benchmark(
    backend: &mut dyn IoSim,
    dataset: &SimDataset,
    nodes: u32,
    threads_per_node: u32,
) -> BenchResult {
    let count = dataset.files.len() as u64;
    // min-heap of (clock, thread)
    let nthreads = (nodes * threads_per_node) as usize;
    let mut heap: BinaryHeap<std::cmp::Reverse<(SimNs, usize)>> = (0..nthreads)
        .map(|t| std::cmp::Reverse((0u64, t)))
        .collect();
    let mut rngs: Vec<crate::util::prng::Prng> = (0..nthreads)
        .map(|t| crate::util::prng::Prng::new(0xB33F ^ (t as u64).wrapping_mul(0x9E3779B97F4A7C15)))
        .collect();
    // reads per thread: split count across the node's threads
    let mut remaining: Vec<u64> = (0..nthreads)
        .map(|t| {
            let tid = (t % threads_per_node as usize) as u64;
            count / threads_per_node as u64
                + if tid < count % threads_per_node as u64 { 1 } else { 0 }
        })
        .collect();
    let mut makespan = 0u64;
    while let Some(std::cmp::Reverse((now, t))) = heap.pop() {
        if remaining[t] == 0 {
            makespan = makespan.max(now);
            continue;
        }
        let node = (t / threads_per_node as usize) as u32;
        let i = rngs[t].index(count as usize);
        let done = backend.read(now, node, &dataset.files[i]);
        remaining[t] -= 1;
        heap.push(std::cmp::Reverse((done, t)));
    }
    let files_read = count * nodes as u64;
    BenchResult {
        file_size: dataset.files.first().map(|f| f.raw).unwrap_or(0),
        files_read,
        seconds: crate::sim::clock::to_secs(makespan),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bench(backend: &mut dyn IoSim, count: u64, size: u64, nodes: u32, parts: u32) -> BenchResult {
        let ds = SimDataset::uniform(count, size, parts, 1.0);
        run_benchmark(backend, &ds, nodes, 4)
    }

    #[test]
    fn fanstore_single_node_close_to_ssd() {
        // Fig 3 shape: FanStore within 71-99% of raw SSD bandwidth.
        for &size in &[128 << 10, 512 << 10, 2 << 20, 8u64 << 20] {
            let count = (256 << 20) / size;
            let fan = bench(&mut FanStoreSim::new(1, 1, 1, Fabric::fdr_infiniband()), count, size, 1, 1);
            let ssd = bench(&mut SsdSim::new(1), count, size, 1, 1);
            let frac = fan.bandwidth_mbs() / ssd.bandwidth_mbs();
            assert!(
                (0.71..=1.05).contains(&frac),
                "size {size}: fanstore/ssd = {frac:.3}"
            );
        }
    }

    #[test]
    fn fuse_2_9_to_4_4x_slower_than_fanstore() {
        for &size in &[128 << 10, 512 << 10, 2 << 20, 8u64 << 20] {
            let count = (256 << 20) / size;
            let fan = bench(&mut FanStoreSim::new(1, 1, 1, Fabric::fdr_infiniband()), count, size, 1, 1);
            let fuse = bench(&mut FuseSim::new(1), count, size, 1, 1);
            let ratio = fan.bandwidth_mbs() / fuse.bandwidth_mbs();
            assert!(
                (2.4..=4.8).contains(&ratio),
                "size {size}: fanstore/fuse = {ratio:.2} (paper band 2.9-4.4)"
            );
        }
    }

    #[test]
    fn sfs_much_slower_especially_small_files() {
        let small_fan = bench(&mut FanStoreSim::new(1, 1, 1, Fabric::fdr_infiniband()), 2048, 128 << 10, 1, 1);
        let small_sfs = bench(&mut SharedFsSim::new(1), 2048, 128 << 10, 1, 1);
        let big_fan = bench(&mut FanStoreSim::new(1, 1, 1, Fabric::fdr_infiniband()), 32, 8 << 20, 1, 1);
        let big_sfs = bench(&mut SharedFsSim::new(1), 32, 8 << 20, 1, 1);
        let small_ratio = small_fan.bandwidth_mbs() / small_sfs.bandwidth_mbs();
        let big_ratio = big_fan.bandwidth_mbs() / big_sfs.bandwidth_mbs();
        assert!(small_ratio > 3.0, "small-file ratio {small_ratio:.1}");
        assert!(big_ratio > 1.0, "big-file ratio {big_ratio:.1}");
        assert!(
            small_ratio > big_ratio,
            "SFS must be worst for small files: {small_ratio:.1} vs {big_ratio:.1}"
        );
    }

    #[test]
    fn multi_node_local_hit_rate_drops_bandwidth_per_node() {
        // 4 nodes, single copy: 25% local; per-node bandwidth below 1-node.
        let one = bench(&mut FanStoreSim::new(1, 4, 1, Fabric::fdr_infiniband()), 512, 2 << 20, 1, 4);
        let four = bench(&mut FanStoreSim::new(4, 4, 1, Fabric::fdr_infiniband()), 512, 2 << 20, 4, 4);
        let per_node_1 = one.bandwidth_mbs();
        let per_node_4 = four.bandwidth_mbs() / 4.0;
        assert!(
            per_node_4 < per_node_1,
            "remote traffic must cost: {per_node_4:.0} vs {per_node_1:.0} MB/s"
        );
        // but aggregate must still grow (Fig 5: 1.0-1.5x from 1 to 4 nodes)
        assert!(four.bandwidth_mbs() > one.bandwidth_mbs() * 0.9);
    }

    #[test]
    fn broadcast_replication_scales_linearly() {
        // replication == nodes: all local, aggregate BW ≈ nodes × single.
        let one = bench(&mut FanStoreSim::new(1, 8, 1, Fabric::omni_path()), 256, 2 << 20, 1, 8);
        let eight = bench(&mut FanStoreSim::new(8, 8, 8, Fabric::omni_path()), 256, 2 << 20, 8, 8);
        let eff = eight.bandwidth_mbs() / (8.0 * one.bandwidth_mbs());
        assert!(eff > 0.9, "broadcast efficiency {eff:.2}");
    }

    #[test]
    fn compressed_reads_move_fewer_bytes() {
        // 2.8x ratio: remote transfers shrink, decompression costs CPU.
        let ds_raw = SimDataset::uniform(512, 2 << 20, 16, 1.0);
        let ds_cmp = SimDataset::uniform(512, 2 << 20, 16, 2.8);
        let mut a = FanStoreSim::new(16, 16, 1, Fabric::omni_path());
        let mut b = FanStoreSim::new(16, 16, 1, Fabric::omni_path());
        let raw = run_benchmark(&mut a, &ds_raw, 16, 4);
        let cmp = run_benchmark(&mut b, &ds_cmp, 16, 4);
        assert!(
            cmp.bandwidth_mbs() > raw.bandwidth_mbs(),
            "at scale compression must win: {:.0} vs {:.0}",
            cmp.bandwidth_mbs(),
            raw.bandwidth_mbs()
        );
    }

    #[test]
    fn metadata_scan_fanstore_vs_sfs() {
        let mut fan = FanStoreSim::new(1, 1, 1, Fabric::fdr_infiniband());
        let mut sfs = SharedFsSim::new(1);
        let t_fan = fan.metadata_scan(0, 0, 1_300_000);
        let t_sfs = sfs.metadata_scan(0, 0, 1_300_000);
        assert!(t_sfs > 10 * t_fan, "sfs metadata {t_sfs} vs fanstore {t_fan}");
    }
}
