//! Plain-text result tables matching the paper's reporting style.

/// A simple aligned table printer.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Self {
        Table {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format helper: `f` with 1 decimal.
pub fn f1(x: f64) -> String {
    format!("{x:.1}")
}

/// Format helper: `f` with 2 decimals.
pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}

/// Format helper: percent with 1 decimal.
pub fn pct(x: f64) -> String {
    format!("{:.1}%", x * 100.0)
}

/// A shape assertion against the paper's claim, printed PASS/FAIL.
pub fn shape_check(label: &str, value: f64, lo: f64, hi: f64) -> bool {
    let ok = value >= lo && value <= hi;
    println!(
        "  shape[{}] {label}: {value:.2} (expected {lo:.2}..{hi:.2})",
        if ok { "PASS" } else { "WARN" }
    );
    ok
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["size", "MB/s"]);
        t.row(&["128K".to_string(), "493.2".to_string()]);
        t.row(&["8M".to_string(), "511.0".to_string()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("128K"));
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 5);
        assert_eq!(lines[3].len(), lines[4].len());
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn row_width_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["1".to_string()]);
    }

    #[test]
    fn shape_check_bounds() {
        assert!(shape_check("t", 2.0, 1.0, 3.0));
        assert!(!shape_check("t", 4.0, 1.0, 3.0));
    }
}
