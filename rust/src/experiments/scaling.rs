//! Fig 5 / Fig 6: benchmark scaling on the GPU cluster ({1,4,8,16} nodes,
//! FDR InfiniBand) and the CPU cluster ({1,64,128,256,512} nodes,
//! Omni-Path).  Single data copy; every node reads the whole directory.

use crate::experiments::iosim::{run_benchmark, FanStoreSim, SimDataset};
use crate::experiments::report::{f1, pct, shape_check, Table};
use crate::net::fabric::Fabric;
use crate::workload::bench::{BenchResult, BenchSpec, BENCH_FILE_SIZES};

/// Which testbed of §6.1.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ClusterKind {
    Gpu,
    Cpu,
}

impl ClusterKind {
    pub fn fabric(&self) -> Fabric {
        match self {
            ClusterKind::Gpu => Fabric::fdr_infiniband(),
            ClusterKind::Cpu => Fabric::omni_path(),
        }
    }

    pub fn node_scales(&self) -> &'static [u32] {
        match self {
            ClusterKind::Gpu => &[1, 4, 8, 16],
            ClusterKind::Cpu => &[1, 64, 128, 256, 512],
        }
    }

    /// Partition count used at prep time (§6.5.2: 48 GPU / 512 CPU).
    pub fn partitions(&self) -> u32 {
        match self {
            ClusterKind::Gpu => 48,
            ClusterKind::Cpu => 512,
        }
    }

    /// The baseline scale the paper computes efficiency against.
    pub fn efficiency_base(&self) -> u32 {
        match self {
            ClusterKind::Gpu => 4,
            ClusterKind::Cpu => 64,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ClusterKind::Gpu => "GPU cluster (FDR IB)",
            ClusterKind::Cpu => "CPU cluster (OPA)",
        }
    }
}

/// results[size_idx][scale_idx]
#[derive(Clone, Debug)]
pub struct ScalingResults {
    pub cluster: ClusterKind,
    pub scales: Vec<u32>,
    pub per_size: Vec<Vec<BenchResult>>,
}

/// Run the scaling benchmark. `count_scale` divides the paper's file counts.
pub fn run(cluster: ClusterKind, count_scale: u64, compression_ratio: f64) -> ScalingResults {
    let spec = BenchSpec::paper(count_scale);
    let scales = cluster.node_scales().to_vec();
    let mut per_size = Vec::new();
    for point in &spec.points {
        let mut row = Vec::new();
        for &nodes in &scales {
            let parts = cluster.partitions().max(nodes);
            let ds = SimDataset::uniform(point.file_count, point.file_size, parts, compression_ratio);
            let mut backend = FanStoreSim::new(nodes, parts, 1, cluster.fabric());
            row.push(run_benchmark(&mut backend, &ds, nodes, 4));
        }
        per_size.push(row);
    }
    ScalingResults {
        cluster,
        scales,
        per_size,
    }
}

/// Weak-scaling efficiency of `r` at scale index `i` vs base index `b`:
/// (BW_i / BW_b) / (N_i / N_b).
pub fn efficiency(res: &ScalingResults, size_idx: usize, i: usize, b: usize) -> f64 {
    let bw_i = res.per_size[size_idx][i].bandwidth_mbs();
    let bw_b = res.per_size[size_idx][b].bandwidth_mbs();
    (bw_i / bw_b) / (res.scales[i] as f64 / res.scales[b] as f64)
}

pub fn report(res: &ScalingResults) {
    let figure = match res.cluster {
        ClusterKind::Gpu => "Fig 5",
        ClusterKind::Cpu => "Fig 6",
    };
    let mut headers: Vec<String> = vec!["file size".into()];
    headers.extend(res.scales.iter().map(|n| format!("{n} nodes")));
    let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut bw = Table::new(
        format!("{figure}a — aggregated bandwidth (MB/s), {}", res.cluster.name()),
        &hdr_refs,
    );
    let mut tp = Table::new(
        format!("{figure}b — aggregated throughput (files/s), {}", res.cluster.name()),
        &hdr_refs,
    );
    for (si, row) in res.per_size.iter().enumerate() {
        let label = crate::util::bytes::human_bytes(BENCH_FILE_SIZES[si]);
        let mut bw_cells = vec![label.clone()];
        let mut tp_cells = vec![label];
        for r in row {
            bw_cells.push(f1(r.bandwidth_mbs()));
            tp_cells.push(f1(r.files_per_sec()));
        }
        bw.row(&bw_cells);
        tp.row(&tp_cells);
    }
    bw.print();
    tp.print();

    // efficiency vs the paper's baseline scale
    let base_idx = res
        .scales
        .iter()
        .position(|&n| n == res.cluster.efficiency_base())
        .unwrap_or(0);
    let last = res.scales.len() - 1;
    println!("weak-scaling efficiency vs {}-node base:", res.scales[base_idx]);
    for (si, _) in res.per_size.iter().enumerate() {
        let eff = efficiency(res, si, last, base_idx);
        println!(
            "  {}: {} at {} nodes",
            crate::util::bytes::human_bytes(BENCH_FILE_SIZES[si]),
            pct(eff),
            res.scales[last]
        );
    }
    let band = match res.cluster {
        ClusterKind::Gpu => (0.70, 1.02), // paper: 76.3%-83.1%
        ClusterKind::Cpu => (0.75, 1.02), // paper: 81.4%-88.2%
    };
    for si in 0..res.per_size.len() {
        // a size is only meaningful when every node holds a few files of it
        let per_node = res.per_size[si][last].files_read
            / (res.scales[last] as u64 * res.scales[last] as u64).max(1);
        if per_node < 2 {
            println!(
                "  shape[SKIP] efficiency size[{si}]: only {} files for {} nodes at this --scale",
                res.per_size[si][last].files_read / res.scales[last] as u64,
                res.scales[last]
            );
            continue;
        }
        shape_check(
            &format!("efficiency size[{si}]"),
            efficiency(res, si, last, base_idx),
            band.0,
            band.1,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gpu_cluster_fig5_shape() {
        let res = run(ClusterKind::Gpu, 64, 1.0);
        // aggregated bandwidth grows with node count for every size
        for row in &res.per_size {
            for w in row.windows(2) {
                assert!(
                    w[1].bandwidth_mbs() > w[0].bandwidth_mbs() * 0.95,
                    "aggregate bandwidth should not collapse"
                );
            }
        }
        // 16-node efficiency vs 4-node base lands in a sane band
        let last = res.scales.len() - 1;
        for si in 0..4 {
            let eff = efficiency(&res, si, last, 1);
            assert!(
                (0.55..=1.05).contains(&eff),
                "size {si}: 16-node efficiency {eff:.2} (paper 76.3-83.1%)"
            );
        }
        // larger files scale no worse than the smallest (paper: "a larger
        // file size produces better scaling performance")
        let eff_small = efficiency(&res, 0, last, 1);
        let eff_big = efficiency(&res, 3, last, 1);
        assert!(eff_big >= eff_small * 0.9);
    }

    #[test]
    fn cpu_cluster_fig6_shape() {
        let res = run(ClusterKind::Cpu, 32, 1.0);
        let last = res.scales.len() - 1;
        let base = 1; // 64 nodes
        // size 3 (8 MB) has only 64 files at this test scale — too few to
        // spread over 512 nodes; check the sizes with real populations.
        for si in 0..2 {
            let eff = efficiency(&res, si, last, base);
            assert!(
                (0.75..=1.05).contains(&eff),
                "size {si}: 512-node efficiency {eff:.2} (paper: 81.4-88.2%)"
            );
        }
        // 1 -> 64 nodes speedup is sub-linear (5.8x-45.4x in the paper)
        for si in 0..2 {
            let s = res.per_size[si][1].bandwidth_mbs() / res.per_size[si][0].bandwidth_mbs();
            assert!((2.0..=64.0).contains(&s), "size {si}: 64-node speedup {s:.1}");
        }
        // larger files speed up more from 1 to 64 (paper: 5.8x small vs 45.4x big)
        let s_small = res.per_size[0][1].bandwidth_mbs() / res.per_size[0][0].bandwidth_mbs();
        let s_big = res.per_size[1][1].bandwidth_mbs() / res.per_size[1][0].bandwidth_mbs();
        // at this reduced test scale the two populated sizes are close;
        // require "no worse" rather than strictly better
        assert!(
            s_big > s_small * 0.95,
            "big {s_big:.1} should not trail small {s_small:.1}"
        );
    }
}

// ---------------------------------------------------------------------------
// Ablation: input replication factor (paper §5.4 "each node can host N
// different partitions") — how locality buys bandwidth at fixed node count.
// ---------------------------------------------------------------------------

/// Aggregated benchmark bandwidth at `nodes` for each replication factor.
pub fn run_replication_ablation(
    cluster: ClusterKind,
    nodes: u32,
    count: u64,
    size: u64,
) -> Vec<(u32, f64, f64)> {
    let mut out = Vec::new();
    let mut r = 1u32;
    while r <= nodes {
        let parts = cluster.partitions().max(nodes);
        let ds = SimDataset::uniform(count, size, parts, 1.0);
        let mut backend = FanStoreSim::new(nodes, parts, r, cluster.fabric());
        let hit = backend.placement.local_hit_rate();
        let res = run_benchmark(&mut backend, &ds, nodes, 4);
        out.push((r, hit, res.bandwidth_mbs()));
        r *= 2;
    }
    out
}

pub fn report_replication_ablation(rows: &[(u32, f64, f64)], nodes: u32) {
    let mut t = Table::new(
        format!("Ablation — replication factor at {nodes} nodes (128 KiB files)"),
        &["replication", "local hit rate", "agg MB/s"],
    );
    for (r, hit, bw) in rows {
        t.row(&[r.to_string(), pct(*hit), f1(*bw)]);
    }
    t.print();
    // shape: bandwidth must increase monotonically with locality
    let monotone = rows.windows(2).all(|w| w[1].2 >= w[0].2 * 0.98);
    println!(
        "  shape[{}] bandwidth monotone in replication factor",
        if monotone { "PASS" } else { "WARN" }
    );
}

// ---------------------------------------------------------------------------
// In-proc pipeline ablation (real cluster, wall clock): sync-per-file vs
// batched vs batched+prefetch remote reads — the §5.4 overlap claim
// measured end to end rather than modelled.
// ---------------------------------------------------------------------------

/// One read strategy's end-to-end result over an identical workload.
#[derive(Clone, Debug)]
pub struct PipelinePoint {
    /// Human label.
    pub mode: &'static str,
    /// Stable key for `BENCH_hotpath.json`.
    pub key: &'static str,
    pub seconds: f64,
    pub files: u64,
    pub bytes: u64,
    /// Worker-served transport requests — the round-trip count batching
    /// amortizes (deterministic, unlike the timings).
    pub requests_served: u64,
}

impl PipelinePoint {
    pub fn files_per_sec(&self) -> f64 {
        self.files as f64 / self.seconds.max(1e-9)
    }

    pub fn bytes_per_sec(&self) -> f64 {
        self.bytes as f64 / self.seconds.max(1e-9)
    }
}

/// Run the same shuffled full-dataset read from node 0 of an
/// `nodes`-node cluster three ways: one synchronous `ReadFile` round trip
/// per file; `prefetch()` mini-batches of `batch` (one `ReadFiles` per
/// owner per mini-batch); and the background prefetch pipeline scheduled
/// with the whole sequence.  Fresh cluster per mode so caches can't leak
/// between strategies.
pub fn run_inproc_pipeline(
    nodes: u32,
    file_count: usize,
    file_size: usize,
    batch: usize,
) -> crate::error::Result<Vec<PipelinePoint>> {
    run_pipeline(
        crate::config::TransportKind::InProc,
        nodes,
        file_count,
        file_size,
        batch,
    )
}

/// [`run_inproc_pipeline`] over an arbitrary fabric — the same cluster
/// logic and workload runs over mpsc channels or loopback TCP sockets.
pub fn run_pipeline(
    transport: crate::config::TransportKind,
    nodes: u32,
    file_count: usize,
    file_size: usize,
    batch: usize,
) -> crate::error::Result<Vec<PipelinePoint>> {
    use crate::config::ClusterConfig;
    use crate::coordinator::Cluster;
    use crate::partition::builder::InputFile;
    use crate::util::prng::Prng;
    use crate::vfs::Vfs;

    let mut rng = Prng::new(0xBA7C);
    let files: Vec<InputFile> = (0..file_count)
        .map(|i| {
            let mut data = vec![0u8; file_size];
            rng.fill_bytes(&mut data);
            InputFile {
                path: format!("train/f{i:05}"),
                data,
            }
        })
        .collect();
    let mut order: Vec<u32> = (0..file_count as u32).collect();
    rng.shuffle(&mut order);

    let mut out = Vec::new();
    for (mode, key) in [
        ("sync per file", "sync_per_file"),
        ("batched", "batched"),
        ("batched+prefetch", "batched_prefetch"),
    ] {
        let cluster = Cluster::launch(
            &files,
            ClusterConfig {
                nodes,
                partitions: nodes * 2,
                transport,
                ..Default::default()
            },
        )?;
        let paths: Vec<String> = files
            .iter()
            .map(|f| format!("/fanstore/user/{}", f.path))
            .collect();
        let mut vfs = if key == "batched_prefetch" {
            cluster.prefetching_client(0)
        } else {
            cluster.client(0)
        };
        if key == "batched_prefetch" {
            // interned index-based schedule: the table is built once, the
            // epoch order rides as u32 indices (sampler index == table index)
            let table =
                std::sync::Arc::new(crate::prefetch::EpochPathTable::from_paths(&paths));
            cluster
                .prefetch_handle(0)
                .schedule_table(&table, order.iter().copied());
            // let the fetchers take the queue before the reader races them,
            // so the measured loop is the steady state, not the cold start
            let t0 = std::time::Instant::now();
            while cluster.prefetch_stats(0).picked == 0 && t0.elapsed().as_millis() < 1000 {
                std::thread::yield_now();
            }
        }
        let t0 = std::time::Instant::now();
        let mut bytes = 0u64;
        match key {
            "batched" => {
                for chunk in order.chunks(batch) {
                    let chunk_paths: Vec<String> =
                        chunk.iter().map(|&i| paths[i as usize].clone()).collect();
                    vfs.prefetch(&chunk_paths)?;
                    for p in &chunk_paths {
                        bytes += vfs.read_all(p)?.len() as u64;
                    }
                }
            }
            // sync-per-file and batched+prefetch share the same plain read
            // loop: the prefetch mode's pipeline feeds it via open's claim
            _ => {
                for &i in &order {
                    bytes += vfs.read_all(&paths[i as usize])?.len() as u64;
                }
            }
        }
        let seconds = t0.elapsed().as_secs_f64();
        drop(vfs);
        let report = cluster.shutdown();
        out.push(PipelinePoint {
            mode,
            key,
            seconds,
            files: file_count as u64,
            bytes,
            requests_served: report.requests_served,
        });
    }
    Ok(out)
}

pub fn report_inproc_pipeline(rows: &[PipelinePoint]) {
    let mut t = Table::new(
        "Pipeline ablation — remote read strategies (in-proc cluster, node-0 reader)",
        &["mode", "MB/s", "files/s", "transport reqs", "speedup"],
    );
    let base = rows
        .first()
        .map(|r| r.files_per_sec())
        .unwrap_or(1.0)
        .max(1e-9);
    for r in rows {
        t.row(&[
            r.mode.to_string(),
            f1(r.bytes_per_sec() / 1e6),
            f1(r.files_per_sec()),
            r.requests_served.to_string(),
            format!("{:.2}x", r.files_per_sec() / base),
        ]);
    }
    t.print();
    if let (Some(sync), Some(pf)) = (rows.first(), rows.last()) {
        shape_check(
            "batched+prefetch round trips < sync round trips",
            if pf.requests_served < sync.requests_served {
                1.0
            } else {
                0.0
            },
            0.5,
            1.5,
        );
    }
}

#[cfg(test)]
mod pipeline_tests {
    use super::*;

    #[test]
    fn pipeline_modes_read_identical_bytes_with_fewer_round_trips() {
        let rows = run_inproc_pipeline(4, 96, 4096, 8).unwrap();
        assert_eq!(rows.len(), 3);
        for r in &rows {
            assert_eq!(r.files, 96);
            assert_eq!(r.bytes, 96 * 4096, "{}: byte total must match", r.mode);
        }
        // batching amortizes round trips: deterministic, unlike wall clock.
        // node 0 holds 2 of 8 partitions -> 72 remote files; sync pays one
        // request per remote file, the batched modes one per holder pickup.
        let sync = &rows[0];
        let batched = &rows[1];
        let prefetch = &rows[2];
        assert!(
            batched.requests_served < sync.requests_served,
            "batched {} !< sync {}",
            batched.requests_served,
            sync.requests_served
        );
        assert!(
            prefetch.requests_served < sync.requests_served,
            "prefetch {} !< sync {}",
            prefetch.requests_served,
            sync.requests_served
        );
    }
}

// ---------------------------------------------------------------------------
// Transport equivalence: the same cluster logic over mpsc channels vs real
// loopback TCP sockets must produce byte-identical reads and the exact same
// stats/cache counter algebra — the wire codec and demux layer add latency,
// never semantics.
// ---------------------------------------------------------------------------

/// One fabric's end-to-end result over the identical workload.
#[derive(Clone, Debug)]
pub struct TransportRun {
    pub kind: crate::config::TransportKind,
    pub seconds: f64,
    pub files_read: u64,
    pub bytes_read: u64,
    /// FNV-1a digest over every file's bytes in each node's read order —
    /// byte-identical runs have identical digests.
    pub digest: u64,
    pub per_node: Vec<crate::node::NodeStats>,
    /// (hits, misses) of each node's refcount cache.
    pub cache: Vec<(u64, u64)>,
    pub requests_served: u64,
}

fn fnv1a(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01B3);
    }
    h
}

/// Run the identical workload (every node reads the full dataset in its own
/// shuffled order, hinted in `batch`-sized mini-batches) on a fresh cluster
/// per fabric; returns one [`TransportRun`] per kind, same order as `kinds`.
pub fn run_transport_equivalence(
    kinds: &[crate::config::TransportKind],
    nodes: u32,
    file_count: usize,
    file_size: usize,
    batch: usize,
) -> crate::error::Result<Vec<TransportRun>> {
    use crate::config::ClusterConfig;
    use crate::coordinator::Cluster;
    use crate::partition::builder::InputFile;
    use crate::util::prng::Prng;
    use crate::vfs::Vfs;
    use std::sync::Arc;

    let mut rng = Prng::new(0x7C9E);
    let files: Vec<InputFile> = (0..file_count)
        .map(|i| {
            let mut data = vec![0u8; file_size];
            rng.fill_bytes(&mut data);
            InputFile {
                path: format!("train/f{i:05}"),
                data,
            }
        })
        .collect();
    let paths: Arc<Vec<String>> = Arc::new(
        files
            .iter()
            .map(|f| format!("/fanstore/user/{}", f.path))
            .collect(),
    );
    // per-node deterministic shuffled order, identical across fabrics
    let orders: Arc<Vec<Vec<u32>>> = Arc::new(
        (0..nodes)
            .map(|n| {
                let mut order: Vec<u32> = (0..file_count as u32).collect();
                Prng::new(0xF00D + n as u64).shuffle(&mut order);
                order
            })
            .collect(),
    );

    let mut out = Vec::new();
    for &kind in kinds {
        let cluster = Arc::new(Cluster::launch(
            &files,
            ClusterConfig {
                nodes,
                partitions: nodes * 2,
                transport: kind,
                ..Default::default()
            },
        )?);
        let t0 = std::time::Instant::now();
        let mut handles = Vec::new();
        for node in 0..nodes {
            let cluster = Arc::clone(&cluster);
            let paths = Arc::clone(&paths);
            let orders = Arc::clone(&orders);
            handles.push(std::thread::spawn(
                move || -> crate::error::Result<(u64, u64)> {
                    let mut vfs = cluster.client(node);
                    let mut digest = 0xCBF2_9CE4_8422_2325u64;
                    let mut bytes = 0u64;
                    for chunk in orders[node as usize].chunks(batch) {
                        let hint: Vec<String> =
                            chunk.iter().map(|&i| paths[i as usize].clone()).collect();
                        vfs.prefetch(&hint)?;
                        for p in &hint {
                            let data = vfs.read_all(p)?;
                            bytes += data.len() as u64;
                            digest = fnv1a(digest, &data);
                        }
                    }
                    Ok((digest, bytes))
                },
            ));
        }
        let mut digest = 0u64;
        let mut bytes_read = 0u64;
        for h in handles {
            let (d, b) = h.join().expect("reader thread")?;
            // order-independent combine of per-node (order-dependent) digests
            digest ^= d;
            bytes_read += b;
        }
        let seconds = t0.elapsed().as_secs_f64();
        let cache: Vec<(u64, u64)> = (0..nodes)
            .map(|n| {
                let cs = cluster.node_state(n).cache.stats();
                (cs.hits, cs.misses)
            })
            .collect();
        let cluster = Arc::try_unwrap(cluster)
            .ok()
            .expect("all reader threads joined");
        let report = cluster.shutdown();
        out.push(TransportRun {
            kind,
            seconds,
            files_read: nodes as u64 * file_count as u64,
            bytes_read,
            digest,
            per_node: report.per_node,
            cache,
            requests_served: report.requests_served,
        });
    }
    Ok(out)
}

/// True iff two fabrics produced byte-identical reads with the exact same
/// counter algebra (the acceptance gauge for the pluggable transport).
pub fn transport_runs_equivalent(a: &TransportRun, b: &TransportRun) -> bool {
    a.digest == b.digest
        && a.bytes_read == b.bytes_read
        && a.files_read == b.files_read
        && a.per_node == b.per_node
        && a.cache == b.cache
        && a.requests_served == b.requests_served
}

pub fn report_transport_equivalence(runs: &[TransportRun]) {
    let mut t = Table::new(
        "Transport equivalence — identical workload per fabric",
        &["fabric", "MB/s", "files/s", "digest", "transport reqs", "remote reads"],
    );
    for r in runs {
        let remote: u64 = r.per_node.iter().map(|s| s.remote_reads_issued).sum();
        t.row(&[
            r.kind.name().to_string(),
            f1(r.bytes_read as f64 / r.seconds.max(1e-9) / 1e6),
            f1(r.files_read as f64 / r.seconds.max(1e-9)),
            format!("{:016x}", r.digest),
            r.requests_served.to_string(),
            remote.to_string(),
        ]);
    }
    t.print();
    if let (Some(a), Some(b)) = (runs.first(), runs.last()) {
        shape_check(
            "tcp run byte- and counter-identical to inproc",
            if transport_runs_equivalent(a, b) { 1.0 } else { 0.0 },
            0.5,
            1.5,
        );
    }
}

#[cfg(test)]
mod ablation_tests {
    use super::*;

    #[test]
    fn replication_monotonically_buys_bandwidth() {
        let rows = run_replication_ablation(ClusterKind::Gpu, 16, 2048, 128 << 10);
        assert_eq!(rows.len(), 5); // r = 1,2,4,8,16
        assert!(rows.last().unwrap().1 > 0.99, "full replication = all local");
        assert!(
            rows.last().unwrap().2 > rows.first().unwrap().2,
            "broadcast must beat single copy: {:?}",
            rows
        );
        for w in rows.windows(2) {
            assert!(w[1].2 >= w[0].2 * 0.95, "non-monotone: {:?}", rows);
        }
    }
}
