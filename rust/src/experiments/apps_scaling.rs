//! App-level simulation: Fig 7 (ResNet-50), Fig 8 (SRGAN), Fig 9 (FRNN)
//! weak scaling, plus the single-node backend comparison reused by Fig 4.
//!
//! Model of one training iteration (paper §3.1/§3.4): the node's 4 I/O
//! threads prefetch the next mini-batch while the accelerator computes; the
//! sustained iteration time is `max(compute, io_span)` (async I/O pipeline,
//! steady state).  Compute times per iteration are calibrated from the
//! paper's own single-node sustained files/s (Fig 4) and held constant
//! across storage backends — storage only moves `io_span`.
//!
//! The SFS application profile is calibrated separately from the §6.2
//! benchmark model: the paper's production Lustre served ResNet at half of
//! FanStore's rate on one node (data-path bound, per-client share ~30 MB/s)
//! while still riding ~7-10k metadata ops/s at 64 nodes (Fig 7) — see
//! DESIGN.md §4 for the calibration notes.

use std::collections::BinaryHeap;

use crate::experiments::iosim::{FanStoreSim, FuseSim, IoSim, SimDataset, SimFile, SsdSim};
use crate::net::fabric::Fabric;
use crate::sim::clock::{transfer_ns, SimNs, MS, US};
use crate::sim::Resource;
use crate::util::prng::Prng;
use crate::workload::datasets::{AppKind, DatasetSpec};

/// Per-iteration application profile (calibrated, see module docs).
#[derive(Clone, Copy, Debug)]
pub struct AppProfile {
    pub kind: AppKind,
    /// Files consumed per node per iteration (mini-batch per node).
    pub batch_per_node: u32,
    /// Accelerator compute per iteration.
    pub compute_ns: SimNs,
    /// Startup metadata entries each process traverses (§3.3).
    pub metadata_entries: u64,
}

impl AppProfile {
    /// ResNet-50 on the GPU cluster: 4 GPUs × 64 batch, ~460 ms/iter ⇒
    /// ~556 files/s sustained with ideal I/O (paper: 544).
    pub fn resnet_gpu() -> Self {
        AppProfile {
            kind: AppKind::ResNet50,
            batch_per_node: 256,
            compute_ns: 460 * MS,
            metadata_entries: 1_302_002,
        }
    }

    /// ResNet-50 on the CPU cluster (2-socket SKX is ~4x slower/node).
    pub fn resnet_cpu() -> Self {
        AppProfile {
            kind: AppKind::ResNet50,
            batch_per_node: 128,
            compute_ns: 900 * MS,
            metadata_entries: 1_302_002,
        }
    }

    /// SRGAN init stage: heavy conv compute, 102 files/s on one node.
    pub fn srgan_init() -> Self {
        AppProfile {
            kind: AppKind::SrganInit,
            batch_per_node: 16,
            compute_ns: 157 * MS,
            metadata_entries: 600_006,
        }
    }

    /// SRGAN adversarial stage: 49 files/s on one node.
    pub fn srgan_train() -> Self {
        AppProfile {
            kind: AppKind::SrganTrain,
            batch_per_node: 16,
            compute_ns: 326 * MS,
            metadata_entries: 600_006,
        }
    }

    /// FRNN on the CPU cluster (broadcast-replicated dataset, Fig 9).
    pub fn frnn() -> Self {
        AppProfile {
            kind: AppKind::Frnn,
            batch_per_node: 128,
            compute_ns: 400 * MS,
            metadata_entries: 171_265,
        }
    }

    pub fn dataset_spec(&self) -> DatasetSpec {
        DatasetSpec::for_app(self.kind)
    }
}

/// Production-Lustre *application* data path (see module docs).
///
/// Calibrated jointly against the paper's two SFS observations:
/// * ResNet-50 @1 GPU node: FanStore 2.0× faster ⇒ the per-*client* file
///   read path costs ~3.6 ms per 108 KB file and does not parallelize
///   across the node's reader threads (llite lock/RPC serialization);
/// * ResNet-50 @64 CPU nodes: FanStore only 1.17× faster ⇒ the shared MDS
///   still sustains ~8 k ops/s, so SFS scales per-client until the MDS
///   queue becomes the residual ~15 % tail.
pub struct SfsAppSim {
    mds: Resource,
    client: Vec<Resource>,
    mds_op_ns: SimNs,
    /// Per-file client-side fixed cost (lock + read RPC round trips).
    client_file_ns: SimNs,
    client_bw: u64,
    rpc_ns: SimNs,
}

impl SfsAppSim {
    pub fn new(nodes: u32) -> Self {
        SfsAppSim {
            mds: Resource::new(1),
            client: (0..nodes).map(|_| Resource::new(1)).collect(),
            mds_op_ns: 120 * US, // ~8.3k metadata ops/s sustained
            client_file_ns: 2_600 * US,
            client_bw: 110_000_000,
            rpc_ns: 250 * US,
        }
    }
}

impl IoSim for SfsAppSim {
    fn read(&mut self, now: SimNs, node: u32, file: &SimFile) -> SimNs {
        let t1 = self.mds.serve(now, self.mds_op_ns) + self.rpc_ns;
        self.client[node as usize].serve(
            t1,
            self.client_file_ns + transfer_ns(file.raw, self.client_bw),
        )
    }

    fn metadata_scan(&mut self, now: SimNs, _node: u32, n_entries: u64) -> SimNs {
        // bulk readdir with large (1024-entry) getdents RPCs + client-side
        // dcache: far cheaper per entry than open()
        let rpcs = n_entries.div_ceil(1024).max(1);
        let mut t = now;
        for _ in 0..rpcs {
            t = self.mds.serve(t, self.mds_op_ns) + self.rpc_ns;
        }
        t
    }

    fn name(&self) -> &'static str {
        "SFS"
    }
}

/// Storage options for the app experiments.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AppBackend {
    FanStore,
    Ssd,
    SsdFuse,
    Sfs,
}

impl AppBackend {
    pub fn name(&self) -> &'static str {
        match self {
            AppBackend::FanStore => "FanStore",
            AppBackend::Ssd => "SSD",
            AppBackend::SsdFuse => "SSD-fuse",
            AppBackend::Sfs => "SFS",
        }
    }
}

/// Weak-scaling run result.
#[derive(Clone, Copy, Debug)]
pub struct AppRunResult {
    pub nodes: u32,
    pub files_per_sec: f64,
    pub io_bound_fraction: f64,
}

/// Options for one app-sim run.
#[derive(Clone, Copy, Debug)]
pub struct AppRunOpts {
    pub nodes: u32,
    pub iters: u32,
    /// Input replication factor (nodes = broadcast, Fig 9).
    pub replication: u32,
    /// Dataset compression ratio (1.0 = off; Fig 10 uses 2.8).
    pub ratio: f64,
    pub fabric: Fabric,
    /// Dataset size in files held by the sim (sampled working set).
    pub dataset_files: u64,
    pub seed: u64,
}

impl AppRunOpts {
    pub fn gpu(nodes: u32) -> Self {
        AppRunOpts {
            nodes,
            iters: 200,
            replication: 1,
            ratio: 1.0,
            fabric: Fabric::fdr_infiniband(),
            dataset_files: 20_000,
            seed: 42,
        }
    }

    pub fn cpu(nodes: u32) -> Self {
        AppRunOpts {
            fabric: Fabric::omni_path(),
            ..Self::gpu(nodes)
        }
    }

    /// Per-app measurement window matching how the paper reports sustained
    /// throughput: SRGAN runs 100 init + 2000 training epochs, so startup
    /// amortizes away; ResNet's window is one 90-epoch-job's steady slice.
    pub fn for_app(kind: crate::workload::datasets::AppKind, nodes: u32) -> Self {
        use crate::workload::datasets::AppKind;
        match kind {
            AppKind::ResNet50 => AppRunOpts::gpu(nodes),
            AppKind::SrganInit | AppKind::SrganTrain => AppRunOpts {
                iters: 600,
                ..AppRunOpts::gpu(nodes)
            },
            AppKind::Frnn => AppRunOpts {
                iters: 300,
                ..AppRunOpts::cpu(nodes)
            },
        }
    }
}

/// Run one app on one backend; returns sustained aggregated files/s.
///
/// Pipeline model (§3.4: "the I/O overlaps with computation"): each node's
/// 4 prefetch threads stream the whole run's reads continuously while the
/// accelerator consumes one batch per `compute_ns`.  The node finishes at
/// `max(io_makespan, scan_end + iters·compute)` — the steady state of a
/// two-stage pipeline.  Reads interleave in the global DES heap at *thread*
/// granularity so shared-resource queueing stays causally ordered at any
/// node count.
pub fn run_app(backend: AppBackend, profile: &AppProfile, opts: &AppRunOpts) -> AppRunResult {
    let spec = profile.dataset_spec();
    let mut rng = Prng::new(opts.seed ^ profile.batch_per_node as u64);
    let sizes: Vec<u64> = (0..opts.dataset_files)
        .map(|_| spec.draw_size(&mut rng))
        .collect();
    let partitions = match backend {
        AppBackend::FanStore => opts.nodes.max(1) * 4,
        _ => 1,
    };
    let ds = SimDataset::from_sizes(&sizes, partitions, opts.ratio);

    let mut sim: Box<dyn IoSim> = match backend {
        AppBackend::FanStore => Box::new(FanStoreSim::new(
            opts.nodes,
            partitions,
            opts.replication,
            opts.fabric,
        )),
        AppBackend::Ssd => Box::new(SsdSim::new(opts.nodes)),
        AppBackend::SsdFuse => Box::new(FuseSim::new(opts.nodes)),
        AppBackend::Sfs => Box::new(SfsAppSim::new(opts.nodes)),
    };

    // startup metadata traversal, every node (§3.3); concurrent arrivals at
    // t=0 serialize naturally on any shared metadata resource
    let scan_end: Vec<SimNs> = (0..opts.nodes)
        .map(|n| sim.metadata_scan(0, n, profile.metadata_entries))
        .collect();

    // stream all reads on nodes×4 prefetch threads
    const THREADS: u64 = 4;
    let total_reads_per_node = opts.iters as u64 * profile.batch_per_node as u64;
    let nthreads = (opts.nodes as u64 * THREADS) as usize;
    let mut remaining: Vec<u64> = (0..nthreads)
        .map(|t| {
            let tid = t as u64 % THREADS;
            total_reads_per_node / THREADS
                + if tid < total_reads_per_node % THREADS { 1 } else { 0 }
        })
        .collect();
    let mut heap: BinaryHeap<std::cmp::Reverse<(SimNs, usize)>> = (0..nthreads)
        .map(|t| std::cmp::Reverse((scan_end[t / THREADS as usize], t)))
        .collect();
    let mut rngs: Vec<Prng> = (0..nthreads)
        .map(|t| Prng::new(opts.seed ^ (t as u64).wrapping_mul(0x9E3779B97F4A7C15)))
        .collect();
    let mut io_end: Vec<SimNs> = scan_end.clone();
    while let Some(std::cmp::Reverse((now, t))) = heap.pop() {
        let node = (t / THREADS as usize) as u32;
        if remaining[t] == 0 {
            io_end[node as usize] = io_end[node as usize].max(now);
            continue;
        }
        let f = &ds.files[rngs[t].index(ds.files.len())];
        let done = sim.read(now, node, f);
        remaining[t] -= 1;
        heap.push(std::cmp::Reverse((done, t)));
    }

    // node completion: pipeline of compute vs streaming I/O
    let mut io_bound_nodes = 0u64;
    let mut makespan = 1u64;
    for n in 0..opts.nodes as usize {
        let compute_end = scan_end[n] + opts.iters as u64 * profile.compute_ns;
        if io_end[n] > compute_end {
            io_bound_nodes += 1;
        }
        makespan = makespan.max(io_end[n].max(compute_end));
    }

    let total_files = opts.nodes as u64 * total_reads_per_node;
    AppRunResult {
        nodes: opts.nodes,
        files_per_sec: total_files as f64 / crate::sim::clock::to_secs(makespan),
        io_bound_fraction: io_bound_nodes as f64 / opts.nodes as f64,
    }
}

/// Weak-scaling efficiency vs a base result.
pub fn weak_efficiency(base: &AppRunResult, at: &AppRunResult) -> f64 {
    (at.files_per_sec / base.files_per_sec) / (at.nodes as f64 / base.nodes as f64)
}

// ---------------------------------------------------------------------------
// Figure drivers (7, 8, 9)
// ---------------------------------------------------------------------------

use crate::experiments::report::{f1, pct, shape_check, Table};

pub struct ScalingSeries {
    pub label: String,
    pub results: Vec<AppRunResult>,
}

/// Fig 7: ResNet-50 weak scaling on both clusters + SFS reference points
/// (4 nodes GPU, 64 nodes CPU — the paper could not run SFS larger).
pub fn run_fig7() -> Vec<ScalingSeries> {
    let mut series = Vec::new();
    let gpu = AppProfile::resnet_gpu();
    series.push(ScalingSeries {
        label: "GPU/FanStore".into(),
        results: [1u32, 4, 8, 16]
            .iter()
            .map(|&n| run_app(AppBackend::FanStore, &gpu, &AppRunOpts::gpu(n)))
            .collect(),
    });
    series.push(ScalingSeries {
        label: "GPU/SFS".into(),
        results: vec![run_app(AppBackend::Sfs, &gpu, &AppRunOpts::gpu(4))],
    });
    let cpu = AppProfile::resnet_cpu();
    series.push(ScalingSeries {
        label: "CPU/FanStore".into(),
        results: [1u32, 64, 128, 256, 512]
            .iter()
            .map(|&n| run_app(AppBackend::FanStore, &cpu, &AppRunOpts::cpu(n)))
            .collect(),
    });
    series.push(ScalingSeries {
        label: "CPU/SFS".into(),
        results: vec![run_app(AppBackend::Sfs, &cpu, &AppRunOpts::cpu(64))],
    });
    series
}

/// Fig 8: SRGAN init + train on the GPU cluster.
pub fn run_fig8() -> Vec<ScalingSeries> {
    [
        ("SRGAN-Init", AppProfile::srgan_init()),
        ("SRGAN-Train", AppProfile::srgan_train()),
    ]
    .into_iter()
    .map(|(label, p)| ScalingSeries {
        label: label.into(),
        results: [1u32, 4, 8, 16]
            .iter()
            .map(|&n| run_app(AppBackend::FanStore, &p, &AppRunOpts::gpu(n)))
            .collect(),
    })
    .collect()
}

/// Fig 9: FRNN on the CPU cluster, broadcast replication, + SFS at 4 nodes.
pub fn run_fig9() -> Vec<ScalingSeries> {
    let p = AppProfile::frnn();
    let fan = ScalingSeries {
        label: "FRNN/FanStore(broadcast)".into(),
        results: [1u32, 4, 16, 64]
            .iter()
            .map(|&n| {
                let mut opts = AppRunOpts::cpu(n);
                opts.replication = n; // whole dataset on every node (§6.5.2)
                run_app(AppBackend::FanStore, &p, &opts)
            })
            .collect(),
    };
    let sfs = ScalingSeries {
        label: "FRNN/SFS".into(),
        results: vec![run_app(AppBackend::Sfs, &p, &AppRunOpts::cpu(4))],
    };
    vec![fan, sfs]
}

pub fn report_series(figure: &str, series: &[ScalingSeries]) {
    let mut t = Table::new(
        format!("{figure} — weak scaling, aggregated files/s"),
        &["series", "nodes", "files/s", "per-node", "io-bound"],
    );
    for s in series {
        for r in &s.results {
            t.row(&[
                s.label.clone(),
                r.nodes.to_string(),
                f1(r.files_per_sec),
                f1(r.files_per_sec / r.nodes as f64),
                pct(r.io_bound_fraction),
            ]);
        }
    }
    t.print();
    for s in series {
        if s.results.len() >= 2 {
            let base = &s.results[if s.results.len() > 3 { 1 } else { 0 }];
            let last = s.results.last().unwrap();
            println!(
                "  {}: efficiency {} at {} nodes (vs {}-node base)",
                s.label,
                pct(weak_efficiency(base, last)),
                last.nodes,
                base.nodes
            );
        }
    }
}

/// The paper's headline shape checks for Figs 7-9.
pub fn shape_checks_fig7(series: &[ScalingSeries]) {
    let find = |l: &str| series.iter().find(|s| s.label == l).unwrap();
    let gpu_fan = find("GPU/FanStore");
    let gpu_sfs = find("GPU/SFS");
    let cpu_fan = find("CPU/FanStore");
    let cpu_sfs = find("CPU/SFS");
    shape_check(
        "GPU 16-node efficiency vs 4 (paper ~100%)",
        weak_efficiency(&gpu_fan.results[1], &gpu_fan.results[3]),
        0.9,
        1.05,
    );
    shape_check(
        "GPU FanStore/SFS @4 nodes (paper 1.761)",
        gpu_fan.results[1].files_per_sec / gpu_sfs.results[0].files_per_sec,
        1.4,
        2.6,
    );
    shape_check(
        "CPU 512-node efficiency vs 64 (paper 95.4%)",
        weak_efficiency(&cpu_fan.results[1], &cpu_fan.results[4]),
        0.85,
        1.02,
    );
    shape_check(
        "CPU FanStore/SFS @64 nodes (paper 1.171)",
        cpu_fan.results[1].files_per_sec / cpu_sfs.results[0].files_per_sec,
        1.05,
        1.6,
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resnet_single_node_rates_fig4() {
        let p = AppProfile::resnet_gpu();
        let fan = run_app(AppBackend::FanStore, &p, &AppRunOpts::gpu(1));
        let ssd = run_app(AppBackend::Ssd, &p, &AppRunOpts::gpu(1));
        let sfs = run_app(AppBackend::Sfs, &p, &AppRunOpts::gpu(1));
        // paper: FanStore 544 files/s sustained
        assert!(
            (450.0..650.0).contains(&fan.files_per_sec),
            "fanstore resnet {:.0} files/s",
            fan.files_per_sec
        );
        // paper: 5.3% faster than SSD (metadata caching) — accept 0-15%
        let vs_ssd = fan.files_per_sec / ssd.files_per_sec;
        assert!((1.0..1.2).contains(&vs_ssd), "fan/ssd {vs_ssd:.3}");
        // paper: 2.0x faster than SFS — accept 1.5-3x
        let vs_sfs = fan.files_per_sec / sfs.files_per_sec;
        assert!((1.5..3.0).contains(&vs_sfs), "fan/sfs {vs_sfs:.2}");
    }

    #[test]
    fn srgan_storage_insensitive_fig4() {
        for p in [AppProfile::srgan_init(), AppProfile::srgan_train()] {
            let opts = AppRunOpts::for_app(p.kind, 1);
            let fan = run_app(AppBackend::FanStore, &p, &opts);
            let ssd = run_app(AppBackend::Ssd, &p, &opts);
            let fuse = run_app(AppBackend::SsdFuse, &p, &opts);
            // paper: "identical performance across all options" (compute-bound)
            for other in [ssd, fuse] {
                let rel = fan.files_per_sec / other.files_per_sec;
                assert!(
                    (0.9..1.15).contains(&rel),
                    "{:?}: fan vs other {rel:.3}",
                    p.kind
                );
            }
        }
    }

    #[test]
    fn srgan_absolute_rates() {
        let init = run_app(
            AppBackend::FanStore,
            &AppProfile::srgan_init(),
            &AppRunOpts::for_app(crate::workload::datasets::AppKind::SrganInit, 1),
        );
        let train = run_app(
            AppBackend::FanStore,
            &AppProfile::srgan_train(),
            &AppRunOpts::for_app(crate::workload::datasets::AppKind::SrganTrain, 1),
        );
        // paper: 102 and 49 files/s
        assert!((85.0..120.0).contains(&init.files_per_sec), "{:.0}", init.files_per_sec);
        assert!((40.0..60.0).contains(&train.files_per_sec), "{:.0}", train.files_per_sec);
    }

    #[test]
    fn resnet_scales_to_16_nodes_fig7() {
        let p = AppProfile::resnet_gpu();
        let base = run_app(AppBackend::FanStore, &p, &AppRunOpts::gpu(4));
        let at16 = run_app(AppBackend::FanStore, &p, &AppRunOpts::gpu(16));
        let eff = weak_efficiency(&base, &at16);
        // paper: "almost 100% on 16 nodes compared to that on four nodes"
        assert!(eff > 0.93, "16-node efficiency {eff:.3}");
    }

    #[test]
    fn frnn_broadcast_scaling_fig9() {
        let p = AppProfile::frnn();
        let mut opts1 = AppRunOpts::cpu(1);
        opts1.replication = 1;
        let base = run_app(AppBackend::FanStore, &p, &opts1);
        let mut opts64 = AppRunOpts::cpu(64);
        opts64.replication = 64; // broadcast: all I/O local (§6.5.2)
        let at64 = run_app(AppBackend::FanStore, &p, &opts64);
        let eff = weak_efficiency(&base, &at64);
        // paper: 93.1% efficiency at 64 nodes
        assert!(eff > 0.85, "frnn 64-node efficiency {eff:.3}");
    }

    #[test]
    fn io_bound_fraction_reported() {
        // SFS ResNet must be I/O bound; FanStore must not be.
        let p = AppProfile::resnet_gpu();
        let fan = run_app(AppBackend::FanStore, &p, &AppRunOpts::gpu(1));
        let sfs = run_app(AppBackend::Sfs, &p, &AppRunOpts::gpu(1));
        assert!(fan.io_bound_fraction < 0.1, "{}", fan.io_bound_fraction);
        assert!(sfs.io_bound_fraction > 0.9, "{}", sfs.io_bound_fraction);
    }
}
