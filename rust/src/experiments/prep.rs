//! §6.3 — data-preparation cost (real packing, measured, then extrapolated
//! to the paper's full-scale datasets).
//!
//! Paper: ImageNet-1k 13 min, SRGAN 11 min, FRNN 14 min on one Xeon node;
//! compressing SRGAN takes 47 min (4.3× the compression-free prep).

use crate::compress::Codec;
use crate::error::Result;
use crate::experiments::report::{f1, f2, shape_check, Table};
use crate::partition::builder::build_partitions;
use crate::workload::datasets::DatasetSpec;

pub struct PrepRow {
    pub dataset: &'static str,
    pub files: usize,
    pub raw_mb: f64,
    pub plain_secs: f64,
    pub compressed_secs: f64,
    pub ratio: f64,
}

/// Pack scaled-down replicas of the three datasets with and without LZSS.
/// `files`/`size_divisor` control the measured working set.
pub fn run(files: usize, size_divisor: u64) -> Result<Vec<PrepRow>> {
    let mut rows = Vec::new();
    for spec in [
        DatasetSpec::imagenet(),
        DatasetSpec::srgan(),
        DatasetSpec::frnn(),
    ] {
        let data = spec.generate(files, size_divisor, 99);
        let (_, plain) = build_partitions(&data, 16, Codec::None)?;
        let (_, compressed) = build_partitions(&data, 16, Codec::Lzss(5))?;
        rows.push(PrepRow {
            dataset: spec.name,
            files,
            raw_mb: plain.raw_bytes as f64 / 1e6,
            plain_secs: plain.wall_seconds,
            compressed_secs: compressed.wall_seconds,
            ratio: compressed.ratio(),
        });
    }
    Ok(rows)
}

pub fn report(rows: &[PrepRow]) {
    let mut t = Table::new(
        "§6.3 — data preparation cost (measured on scaled datasets)",
        &[
            "dataset",
            "files",
            "MB",
            "pack (s)",
            "pack+LZSS (s)",
            "slowdown",
            "ratio",
        ],
    );
    for r in rows {
        t.row(&[
            r.dataset.to_string(),
            r.files.to_string(),
            f1(r.raw_mb),
            format!("{:.3}", r.plain_secs),
            format!("{:.3}", r.compressed_secs),
            f2(r.compressed_secs / r.plain_secs.max(1e-9)),
            f2(r.ratio),
        ]);
    }
    t.print();
    println!("shape checks vs paper §6.3/§6.6:");
    let srgan = rows.iter().find(|r| r.dataset == "srgan-em").unwrap();
    shape_check(
        "SRGAN compression prep slowdown (paper 4.3x)",
        srgan.compressed_secs / srgan.plain_secs.max(1e-9),
        1.5,
        8.0,
    );
    shape_check("SRGAN compression ratio (paper 2.8x)", srgan.ratio, 1.9, 4.5);
    let imagenet = rows.iter().find(|r| r.dataset == "imagenet-1k").unwrap();
    shape_check(
        "ImageNet ratio ~1 (paper: no room)",
        imagenet.ratio,
        1.0,
        1.3,
    );
    // extrapolate throughput to the paper's full datasets
    println!("full-scale extrapolation (single core):");
    for r in rows {
        let bytes_per_sec = r.raw_mb * 1e6 / r.plain_secs.max(1e-9);
        let spec = match r.dataset {
            "imagenet-1k" => DatasetSpec::imagenet(),
            "srgan-em" => DatasetSpec::srgan(),
            _ => DatasetSpec::frnn(),
        };
        let full_min = spec.full_bytes as f64 / bytes_per_sec / 60.0;
        println!(
            "  {}: {:.1} min to pack {} (paper: 13/11/14 min on a 2680)",
            r.dataset,
            full_min,
            crate::util::bytes::human_bytes(spec.full_bytes)
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prep_rows_and_compression_slowdown() {
        let rows = run(200, 64).unwrap();
        assert_eq!(rows.len(), 3);
        let srgan = rows.iter().find(|r| r.dataset == "srgan-em").unwrap();
        // compression must cost real extra time and deliver a real ratio
        assert!(srgan.compressed_secs > srgan.plain_secs);
        assert!(srgan.ratio > 1.9, "srgan ratio {}", srgan.ratio);
        let im = rows.iter().find(|r| r.dataset == "imagenet-1k").unwrap();
        assert!(im.ratio < 1.3, "imagenet ratio {}", im.ratio);
    }
}
