//! Fig 4: application training throughput (files/s) on the four storage
//! options, single node.

use crate::experiments::apps_scaling::{run_app, AppBackend, AppProfile, AppRunOpts};
use crate::experiments::report::{f1, shape_check, Table};

pub struct AppRow {
    pub app: &'static str,
    pub per_backend: Vec<(&'static str, f64)>,
}

pub fn run() -> Vec<AppRow> {
    let backends = [
        AppBackend::FanStore,
        AppBackend::Ssd,
        AppBackend::SsdFuse,
        AppBackend::Sfs,
    ];
    let profiles = [
        AppProfile::resnet_gpu(),
        AppProfile::srgan_init(),
        AppProfile::srgan_train(),
        AppProfile::frnn(),
    ];
    profiles
        .iter()
        .map(|p| AppRow {
            app: p.kind.name(),
            per_backend: backends
                .iter()
                .map(|&b| {
                    let opts = AppRunOpts::for_app(p.kind, 1);
                    (b.name(), run_app(b, p, &opts).files_per_sec)
                })
                .collect(),
        })
        .collect()
}

pub fn report(rows: &[AppRow]) {
    let mut t = Table::new(
        "Fig 4 — training throughput (files/s) by storage backend, 1 node",
        &["app", "FanStore", "SSD", "SSD-fuse", "SFS"],
    );
    for row in rows {
        let mut cells = vec![row.app.to_string()];
        for (_, v) in &row.per_backend {
            cells.push(f1(*v));
        }
        t.row(&cells);
    }
    t.print();

    let get = |app: &str, backend: &str| {
        rows.iter()
            .find(|r| r.app == app)
            .unwrap()
            .per_backend
            .iter()
            .find(|(b, _)| *b == backend)
            .unwrap()
            .1
    };
    println!("shape checks vs paper §6.4.2:");
    shape_check(
        "ResNet-50 FanStore files/s (paper 544)",
        get("ResNet-50", "FanStore"),
        450.0,
        650.0,
    );
    shape_check(
        "ResNet-50 FanStore/SSD (paper 1.053)",
        get("ResNet-50", "FanStore") / get("ResNet-50", "SSD"),
        1.0,
        1.2,
    );
    shape_check(
        "ResNet-50 FanStore/SFS (paper 2.0)",
        get("ResNet-50", "FanStore") / get("ResNet-50", "SFS"),
        1.5,
        3.0,
    );
    shape_check(
        "SRGAN-Init FanStore files/s (paper 102)",
        get("SRGAN-Init", "FanStore"),
        85.0,
        120.0,
    );
    shape_check(
        "SRGAN-Train FanStore files/s (paper 49)",
        get("SRGAN-Train", "FanStore"),
        40.0,
        60.0,
    );
    for app in ["SRGAN-Init", "SRGAN-Train", "FRNN"] {
        let fan = get(app, "FanStore");
        let worst = ["SSD", "SSD-fuse"]
            .iter()
            .map(|b| get(app, b))
            .fold(f64::INFINITY, f64::min);
        shape_check(
            &format!("{app} storage-insensitive (local opts within 15%)"),
            fan / worst,
            0.85,
            1.18,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig4_rows_complete() {
        let rows = run();
        assert_eq!(rows.len(), 4);
        for r in &rows {
            assert_eq!(r.per_backend.len(), 4);
            for (b, v) in &r.per_backend {
                assert!(*v > 0.0, "{} on {b} produced zero throughput", r.app);
            }
        }
    }
}
