//! Fig 10 (SRGAN ± compression across scales) and Fig 11 (relative
//! bandwidth/throughput of compressed vs uncompressed benchmark reads).
//!
//! Paper §6.6: SRGAN's dataset compresses 2.8×; compressed runs are
//! 2.8–11.6 % faster at app level; at benchmark level small files on one
//! node *lose* (~50 % — decompression is CPU-bound) while everything wins
//! at scale (traffic shifts to the interconnect and compressed transfers
//! move 2.8× fewer bytes).

use crate::experiments::apps_scaling::{run_app, AppBackend, AppProfile, AppRunOpts};
use crate::experiments::iosim::{run_benchmark, FanStoreSim, SimDataset};
use crate::experiments::report::{f1, f2, pct, shape_check, Table};
use crate::net::fabric::Fabric;
use crate::workload::bench::{BenchSpec, BENCH_FILE_SIZES};

pub const SRGAN_RATIO: f64 = 2.8;

/// Fig 10: SRGAN init+train throughput with and without compression on the
/// GPU cluster at {1, 4, 8, 16} nodes.
pub struct Fig10Row {
    pub stage: &'static str,
    pub nodes: u32,
    pub plain: f64,
    pub compressed: f64,
}

pub fn run_fig10() -> Vec<Fig10Row> {
    let mut rows = Vec::new();
    for (stage, profile) in [
        ("SRGAN-Init", AppProfile::srgan_init()),
        ("SRGAN-Train", AppProfile::srgan_train()),
    ] {
        for &nodes in &[1u32, 4, 8, 16] {
            let mut opts = AppRunOpts::gpu(nodes);
            let plain = run_app(AppBackend::FanStore, &profile, &opts).files_per_sec;
            opts.ratio = SRGAN_RATIO;
            let compressed = run_app(AppBackend::FanStore, &profile, &opts).files_per_sec;
            rows.push(Fig10Row {
                stage,
                nodes,
                plain,
                compressed,
            });
        }
    }
    rows
}

pub fn report_fig10(rows: &[Fig10Row]) {
    let mut t = Table::new(
        "Fig 10 — SRGAN throughput (files/s) ± LZSS-compressed data, GPU cluster",
        &["stage", "nodes", "plain", "compressed", "delta"],
    );
    for r in rows {
        t.row(&[
            r.stage.to_string(),
            r.nodes.to_string(),
            f1(r.plain),
            f1(r.compressed),
            pct(r.compressed / r.plain - 1.0),
        ]);
    }
    t.print();
    println!("shape checks vs paper §6.6 (compressed within -5%..+15% of plain):");
    for r in rows {
        shape_check(
            &format!("{} @{} nodes", r.stage, r.nodes),
            r.compressed / r.plain,
            0.95,
            1.15,
        );
    }
}

/// Fig 11: relative benchmark bandwidth/throughput (compressed vs plain)
/// across CPU-cluster scales.  rel[size][scale].
pub struct Fig11Results {
    pub scales: Vec<u32>,
    pub relative_bw: Vec<Vec<f64>>,
}

pub fn run_fig11(count_scale: u64) -> Fig11Results {
    let scales: Vec<u32> = vec![1, 64, 128, 256, 512];
    let spec = BenchSpec::paper(count_scale);
    let mut relative_bw = Vec::new();
    for point in &spec.points {
        let mut row = Vec::new();
        for &nodes in &scales {
            let parts = 512.max(nodes);
            let run_one = |ratio: f64| {
                let ds = SimDataset::uniform(point.file_count, point.file_size, parts, ratio);
                let mut backend = FanStoreSim::new(nodes, parts, 1, Fabric::omni_path());
                run_benchmark(&mut backend, &ds, nodes, 4).bandwidth_mbs()
            };
            let plain = run_one(1.0);
            let compressed = run_one(SRGAN_RATIO);
            row.push(compressed / plain);
        }
        relative_bw.push(row);
    }
    Fig11Results {
        scales,
        relative_bw,
    }
}

pub fn report_fig11(res: &Fig11Results) {
    let mut headers: Vec<String> = vec!["file size".into()];
    headers.extend(res.scales.iter().map(|n| format!("{n} nodes")));
    let hdr: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(
        "Fig 11 — relative bandwidth, compressed (2.8x) / uncompressed",
        &hdr,
    );
    for (si, row) in res.relative_bw.iter().enumerate() {
        let mut cells = vec![crate::util::bytes::human_bytes(BENCH_FILE_SIZES[si])];
        cells.extend(row.iter().map(|&v| f2(v)));
        t.row(&cells);
    }
    t.print();
    println!("shape checks vs paper §6.6:");
    // single node: small files slower with compression (CPU-bound decode)
    shape_check(
        "128KB @1 node (paper ~0.5)",
        res.relative_bw[0][0],
        0.3,
        0.95,
    );
    // large files at scale: compression wins clearly
    shape_check(
        "8MB @512 nodes (>1)",
        res.relative_bw[3][res.scales.len() - 1],
        1.05,
        3.5,
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig11_crossover_shape() {
        let res = run_fig11(64);
        // at scale every size should benefit (paper: higher I/O bandwidth
        // and throughput across scales once traffic is interconnect-bound)
        let last = res.scales.len() - 1;
        // size 3 (8 MB) has too few files at this test scale to populate
        // 512 nodes; check the well-populated sizes.
        for (si, row) in res.relative_bw.iter().take(3).enumerate() {
            assert!(
                row[last] > 0.95,
                "size {si} at 512 nodes: rel {:.2}",
                row[last]
            );
            // compression helps MORE at scale than on one node
            assert!(
                row[last] > row[0],
                "size {si}: {:.2} -> {:.2} must improve with scale",
                row[0],
                row[last]
            );
        }
        // single-node small files pay the decompression tax
        assert!(res.relative_bw[0][0] < 1.0);
    }

    #[test]
    fn fig10_compression_never_catastrophic() {
        let rows = run_fig10();
        for r in rows {
            let rel = r.compressed / r.plain;
            assert!(
                rel > 0.9,
                "{} @{}: compressed/plain {rel:.2}",
                r.stage,
                r.nodes
            );
        }
    }
}
