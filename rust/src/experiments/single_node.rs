//! Fig 3: single-node bandwidth (MB/s) and throughput (files/s) for
//! FanStore vs SSD vs SSD-fuse vs SFS across the four benchmark file sizes.

use crate::experiments::iosim::{
    run_benchmark, FanStoreSim, FuseSim, IoSim, SharedFsSim, SimDataset, SsdSim,
};
use crate::experiments::report::{f1, shape_check, Table};
use crate::net::fabric::Fabric;
use crate::workload::bench::{BenchResult, BenchSpec};

/// One backend's results across the four sizes.
#[derive(Clone, Debug)]
pub struct BackendRow {
    pub backend: &'static str,
    pub results: Vec<BenchResult>,
}

/// Run Fig 3. `scale` divides the paper's file counts (1 = full-scale
/// virtual workload; benches use 8, tests use higher).
pub fn run(scale: u64) -> Vec<BackendRow> {
    let spec = BenchSpec::paper(scale);
    let mut rows = Vec::new();
    let backends: Vec<Box<dyn FnMut() -> Box<dyn IoSim>>> = vec![
        Box::new(|| Box::new(FanStoreSim::new(1, 1, 1, Fabric::fdr_infiniband()))),
        Box::new(|| Box::new(SsdSim::new(1))),
        Box::new(|| Box::new(FuseSim::new(1))),
        Box::new(|| Box::new(SharedFsSim::new(1))),
    ];
    for mut mk in backends {
        let mut results = Vec::new();
        let mut name = "";
        for point in &spec.points {
            let ds = SimDataset::uniform(point.file_count, point.file_size, 1, 1.0);
            let mut backend = mk();
            name = backend.name();
            results.push(run_benchmark(backend.as_mut(), &ds, 1, 4));
        }
        rows.push(BackendRow {
            backend: name,
            results,
        });
    }
    rows
}

/// Print the Fig 3 tables + the paper's shape checks.
pub fn report(rows: &[BackendRow]) {
    let sizes = ["128KB", "512KB", "2MB", "8MB"];
    let mut bw = Table::new(
        "Fig 3a — single-node bandwidth (MB/s)",
        &["backend", sizes[0], sizes[1], sizes[2], sizes[3]],
    );
    let mut tp = Table::new(
        "Fig 3b — single-node throughput (files/s)",
        &["backend", sizes[0], sizes[1], sizes[2], sizes[3]],
    );
    for row in rows {
        let mut bw_cells = vec![row.backend.to_string()];
        let mut tp_cells = vec![row.backend.to_string()];
        for r in &row.results {
            bw_cells.push(f1(r.bandwidth_mbs()));
            tp_cells.push(f1(r.files_per_sec()));
        }
        bw.row(&bw_cells);
        tp.row(&tp_cells);
    }
    bw.print();
    tp.print();

    let get = |name: &str| rows.iter().find(|r| r.backend == name).unwrap();
    let fan = get("FanStore");
    let ssd = get("SSD");
    let fuse = get("SSD-fuse");
    let sfs = get("SFS");
    println!("shape checks vs paper §6.4.1:");
    for (i, _) in fan.results.iter().enumerate() {
        shape_check(
            &format!("FanStore/SSD bw frac @{}", sizes[i]),
            fan.results[i].bandwidth_mbs() / ssd.results[i].bandwidth_mbs(),
            0.71,
            1.05,
        );
        shape_check(
            &format!("FanStore/fuse speedup @{}", sizes[i]),
            fan.results[i].bandwidth_mbs() / fuse.results[i].bandwidth_mbs(),
            1.8,
            6.0,
        );
        shape_check(
            &format!("FanStore/SFS speedup @{}", sizes[i]),
            fan.results[i].bandwidth_mbs() / sfs.results[i].bandwidth_mbs(),
            2.0,
            80.0,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_orderings_hold() {
        let rows = run(256); // scaled down for test speed
        let by = |name: &str| {
            rows.iter()
                .find(|r| r.backend == name)
                .unwrap()
                .results
                .iter()
                .map(|r| r.bandwidth_mbs())
                .collect::<Vec<_>>()
        };
        let fan = by("FanStore");
        let ssd = by("SSD");
        let fuse = by("SSD-fuse");
        let sfs = by("SFS");
        for i in 0..4 {
            assert!(fan[i] <= ssd[i] * 1.05, "FanStore bounded by raw SSD");
            assert!(fan[i] > fuse[i], "FanStore beats FUSE @{i}");
            assert!(fan[i] > sfs[i], "FanStore beats SFS @{i}");
        }
        // SFS is *worst* for the smallest files (metadata-bound)
        let deficit_small = fan[0] / sfs[0];
        let deficit_big = fan[3] / sfs[3];
        assert!(deficit_small > deficit_big);
    }
}
