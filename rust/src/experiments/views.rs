//! Fig 1 — test accuracy with the global vs partitioned dataset view.
//!
//! This is the one experiment that must run *real training*: a CNN
//! surrogate trained through the full FanStore read path + PJRT train step,
//! once with every node sampling the whole dataset (global view) and once
//! with each node locked to an exclusive shard (partitioned view).  The
//! paper reports a ~4 % test-accuracy gap on ResNet-50/ImageNet; with the
//! class-banded synthetic set the gap reproduces qualitatively (partitioned
//! nodes overfit their shard's class mix and the averaged model
//! underperforms).

use crate::config::ClusterConfig;
use crate::coordinator::Cluster;
use crate::error::Result;
use crate::experiments::report::{pct, Table};
use crate::runtime::Engine;
use crate::trainer::data::gen_classification_dataset;
use crate::trainer::{train_cnn, DatasetView, TrainConfig, TrainLog};

pub struct ViewRun {
    pub view: DatasetView,
    pub log: TrainLog,
}

/// Train twice (global, partitioned) on a fresh cluster each time.
pub fn run(
    engine: &Engine,
    nodes: u32,
    train_files: usize,
    test_files: usize,
    epochs: u32,
    max_steps: Option<u32>,
) -> Result<Vec<ViewRun>> {
    let mut out = Vec::new();
    for view in [DatasetView::Global, DatasetView::Partitioned] {
        let mut files = gen_classification_dataset(train_files, "train", 11);
        files.extend(gen_classification_dataset(test_files, "test", 23));
        let cfg = ClusterConfig {
            nodes,
            partitions: nodes * 2,
            replicate_dirs: vec!["test".into()],
            ..Default::default()
        };
        let mount = cfg.mount.clone();
        let cluster = Cluster::launch(&files, cfg)?;
        let train_paths: Vec<String> = files
            .iter()
            .filter(|f| f.path.starts_with("train"))
            .map(|f| format!("{mount}/{}", f.path))
            .collect();
        let test_paths: Vec<String> = files
            .iter()
            .filter(|f| f.path.starts_with("test"))
            .map(|f| format!("{mount}/{}", f.path))
            .collect();
        let tc = TrainConfig {
            epochs,
            max_steps_per_epoch: max_steps,
            view,
            lr: 0.05,
            seed: 7,
            checkpoint: true,
            flip_prob: 0.0,
            prefetch: true,
        };
        let log = train_cnn(&cluster, engine, &train_paths, &test_paths, &tc)?;
        cluster.shutdown();
        out.push(ViewRun { view, log });
    }
    Ok(out)
}

pub fn report(runs: &[ViewRun]) {
    let mut t = Table::new(
        "Fig 1 — test accuracy: global vs partitioned dataset view",
        &["view", "epoch", "mean loss", "train acc", "test acc"],
    );
    for r in runs {
        for e in &r.log.epochs {
            t.row(&[
                format!("{:?}", r.view),
                e.epoch.to_string(),
                format!("{:.4}", e.mean_loss),
                pct(e.train_acc as f64),
                pct(e.test_acc as f64),
            ]);
        }
    }
    t.print();
    let global = runs
        .iter()
        .find(|r| r.view == DatasetView::Global)
        .map(|r| r.log.final_test_acc())
        .unwrap_or(0.0);
    let partitioned = runs
        .iter()
        .find(|r| r.view == DatasetView::Partitioned)
        .map(|r| r.log.final_test_acc())
        .unwrap_or(0.0);
    println!(
        "final test accuracy: global {} vs partitioned {} (gap {})",
        pct(global as f64),
        pct(partitioned as f64),
        pct((global - partitioned) as f64)
    );
    // convergence-gap view: mean test accuracy across the run (the area
    // under the accuracy curve the paper's Fig 1 plots per epoch)
    let auc = |view: DatasetView| -> f64 {
        runs.iter()
            .find(|r| r.view == view)
            .map(|r| {
                r.log.epochs.iter().map(|e| e.test_acc as f64).sum::<f64>()
                    / r.log.epochs.len().max(1) as f64
            })
            .unwrap_or(0.0)
    };
    let (g_auc, p_auc) = (auc(DatasetView::Global), auc(DatasetView::Partitioned));
    println!(
        "mean test accuracy over the run: global {} vs partitioned {} (gap {})",
        pct(g_auc),
        pct(p_auc),
        pct(g_auc - p_auc)
    );
    println!(
        "paper: partitioned view trails by ~4% on ResNet-50/ImageNet.  With the\n\
         surrogate (plain synchronous SGD, no BatchNorm, linearly-separable toy\n\
         task) the *asymptotic* gap closes once both saturate; the partitioned\n\
         view's deficit shows as slower convergence (per-epoch gap above).\n\
         Shape target: global >= partitioned at every epoch."
    );
}
