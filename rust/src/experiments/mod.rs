//! Experiment harness: one module per paper figure/table (§6).
//!
//! | module           | regenerates                                        |
//! |------------------|----------------------------------------------------|
//! | [`views`]        | Fig 1  — global vs partitioned test accuracy       |
//! | [`single_node`]  | Fig 3  — single-node BW/throughput, 4 backends     |
//! | [`apps`]         | Fig 4  — app throughput on 4 backends              |
//! | [`scaling`]      | Fig 5/6 — benchmark scaling, GPU + CPU clusters    |
//! | [`apps_scaling`] | Fig 7/8/9 — app weak scaling                       |
//! | [`compression`]  | Fig 10/11 — compressed-data performance            |
//! | [`prep`]         | §6.3 — data-preparation cost                       |
//! | [`failover`]     | PR 7 — kill-a-node-mid-sweep survival drill        |
//!
//! All figures are regenerated on the virtual-time simulator ([`iosim`])
//! except Fig 1 (real training through PJRT) and the prep table (real
//! packing).  Numbers are *shape* targets (who wins, by what factor, where
//! crossovers fall), not testbed-exact — see DESIGN.md §4.

pub mod apps;
pub mod apps_scaling;
pub mod compression;
pub mod failover;
pub mod iosim;
pub mod prep;
pub mod report;
pub mod scaling;
pub mod single_node;
pub mod views;

pub use report::Table;
