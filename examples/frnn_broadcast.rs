//! FRNN broadcast-replication mode (paper §6.5.2, Fig 9).
//!
//! The FRNN dataset (54 GB) fits in every node's local SSD (144 GB), so the
//! paper "simply uses FanStore's broadcast function to replicate the
//! dataset across all nodes — all I/O traffic is completed within the local
//! node".  This example demonstrates exactly that on the real in-process
//! cluster (replication == nodes ⇒ zero remote fetches), trains the LSTM
//! surrogate through the pipeline via PJRT, and reruns the Fig 9 scaling
//! simulation.
//!
//! Run: `make artifacts && cargo run --release --offline --example frnn_broadcast`

use fanstore::config::ClusterConfig;
use fanstore::coordinator::Cluster;
use fanstore::runtime::tensor::Tensor;
use fanstore::runtime::Engine;
use fanstore::util::prng::Prng;
use fanstore::vfs::Vfs;
use fanstore::workload::datasets::DatasetSpec;

/// FRNN "shot" file: T x F f32 diagnostics + 1 label byte.
const T: usize = 16;
const F: usize = 16;

fn gen_shots(n: usize, seed: u64) -> Vec<fanstore::partition::builder::InputFile> {
    let mut rng = Prng::new(seed);
    (0..n)
        .map(|i| {
            let disrupt = rng.chance(0.5);
            let mut vals = vec![0f32; T * F];
            for (j, v) in vals.iter_mut().enumerate() {
                *v = rng.normal() as f32;
                // disruptions: strong signal in the last quarter window
                if disrupt && j / F >= 3 * T / 4 {
                    *v += 2.5;
                }
            }
            let mut data = Vec::with_capacity(T * F * 4 + 1);
            for v in &vals {
                data.extend_from_slice(&v.to_le_bytes());
            }
            data.push(disrupt as u8);
            fanstore::partition::builder::InputFile {
                path: format!("shots/shot{i:06}.sig"),
                data,
            }
        })
        .collect()
}

fn main() -> fanstore::Result<()> {
    let artifacts = std::env::var("FANSTORE_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let engine = Engine::load_subset(&artifacts, &["lstm_train_step"])?;
    let spec = engine.spec("lstm_train_step")?.clone();
    let n_params = spec.param_count();
    let batch = spec.inputs[n_params].dims[0];
    let mut params = spec.load_params()?;

    println!("generating {} tokamak shot files (FRNN profile: single flat dir)", 1024);
    let files = gen_shots(1024, 99);
    assert_eq!(DatasetSpec::frnn().full_dirs, 1, "FRNN is one flat directory");

    let nodes = 4u32;
    let cfg = ClusterConfig {
        nodes,
        partitions: nodes,
        replication: nodes, // broadcast: every node holds everything
        ..Default::default()
    };
    let mount = cfg.mount.clone();
    let cluster = Cluster::launch(&files, cfg)?;
    let paths: Vec<String> = files
        .iter()
        .map(|f| format!("{mount}/{}", f.path))
        .collect();

    println!("training LSTM surrogate for 60 steps through the broadcast store...");
    let mut clients: Vec<_> = (0..nodes).map(|n| cluster.client(n)).collect();
    let mut rng = Prng::new(3);
    let mut last_loss = f32::NAN;
    let mut first_loss = f32::NAN;
    for step in 0..60 {
        let mut replicas = Vec::new();
        for node in 0..nodes as usize {
            // read a mini-batch of shot files through the VFS
            let mut x = Vec::with_capacity(batch * T * F);
            let mut y = Vec::with_capacity(batch);
            for _ in 0..batch {
                let p = &paths[rng.index(paths.len())];
                let bytes = clients[node].read_all(p)?;
                for c in bytes[..T * F * 4].chunks_exact(4) {
                    x.push(f32::from_le_bytes(c.try_into().unwrap()));
                }
                y.push(*bytes.last().unwrap() as f32);
            }
            let mut inputs = params.clone();
            inputs.push(Tensor::from_f32(&[batch, T, F], &x));
            inputs.push(Tensor::from_f32(&[batch], &y));
            inputs.push(Tensor::scalar_f32(0.1));
            let out = engine.execute("lstm_train_step", &inputs)?;
            replicas.push(out[..n_params].to_vec());
            last_loss = out[n_params].scalar_value()?;
        }
        params = fanstore::trainer::allreduce_mean(&replicas)?;
        if step == 0 {
            first_loss = last_loss;
        }
        if step % 10 == 0 {
            println!("  step {step:>3}: BCE loss {last_loss:.4}");
        }
    }
    println!("loss: {first_loss:.4} -> {last_loss:.4}");
    assert!(last_loss < first_loss, "LSTM failed to learn");

    let report = cluster.shutdown();
    let remote: u64 = report.per_node.iter().map(|s| s.remote_reads_issued).sum();
    println!("remote fetches under broadcast replication: {remote} (must be 0)");
    assert_eq!(remote, 0, "broadcast mode must serve everything locally");

    println!("\nsimulated Fig 9 scaling:");
    let series = fanstore::experiments::apps_scaling::run_fig9();
    fanstore::experiments::apps_scaling::report_series("Fig 9 (FRNN)", &series);
    println!("frnn_broadcast OK");
    Ok(())
}
