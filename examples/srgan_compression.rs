//! SRGAN compression study (paper §6.6, Figs 10/11).
//!
//! Uses the real LZSS codec end to end:
//!   * packs an SRGAN-profile dataset (Table 2 statistics, ~2.8x
//!     compressible) with and without compression, reporting the real prep
//!     cost and ratio (§6.3's 4.3x prep slowdown);
//!   * serves both variants from an in-process cluster and measures the
//!     wall-clock read path (remote fetches move compressed bytes,
//!     decompression on the reader — §5.4);
//!   * reruns Fig 10 on the simulated GPU cluster for the scale trend.
//!
//! Run: `cargo run --release --offline --example srgan_compression`

use fanstore::compress::Codec;
use fanstore::config::ClusterConfig;
use fanstore::coordinator::Cluster;
use fanstore::util::{human_bytes, human_rate};
use fanstore::vfs::Vfs;
use fanstore::workload::datasets::DatasetSpec;

fn serve(codec: Codec, files: &[fanstore::partition::builder::InputFile]) -> fanstore::Result<(f64, f64)> {
    let cfg = ClusterConfig {
        nodes: 4,
        partitions: 8,
        codec,
        ..Default::default()
    };
    let mount = cfg.mount.clone();
    let cluster = Cluster::launch(files, cfg)?;
    let ratio = cluster.prep_stats.ratio();
    let paths: Vec<String> = files
        .iter()
        .map(|f| format!("{mount}/{}", f.path))
        .collect();
    let t0 = std::time::Instant::now();
    let mut handles = Vec::new();
    for node in 0..4u32 {
        let mut vfs = cluster.client(node);
        let paths = paths.clone();
        handles.push(std::thread::spawn(move || -> fanstore::Result<u64> {
            let mut bytes = 0u64;
            for p in &paths {
                bytes += vfs.read_all(p)?.len() as u64;
            }
            Ok(bytes)
        }));
    }
    let mut total = 0u64;
    for h in handles {
        total += h.join().expect("reader")?;
    }
    let bw = total as f64 / t0.elapsed().as_secs_f64();
    cluster.shutdown();
    Ok((bw, ratio))
}

fn main() -> fanstore::Result<()> {
    let spec = DatasetSpec::srgan();
    println!(
        "SRGAN-profile dataset: full scale {} files / {}, generating scaled replica...",
        spec.full_files,
        human_bytes(spec.full_bytes)
    );
    let files = spec.generate(240, 16, 55);
    let raw: u64 = files.iter().map(|f| f.data.len() as u64).sum();
    println!("scaled replica: {} files, {}", files.len(), human_bytes(raw));

    // prep cost ± compression (real packing, real codec)
    let t0 = std::time::Instant::now();
    let (_, plain) =
        fanstore::partition::builder::build_partitions(&files, 8, Codec::None)?;
    let t_plain = t0.elapsed().as_secs_f64();
    let t0 = std::time::Instant::now();
    let (_, packed) =
        fanstore::partition::builder::build_partitions(&files, 8, Codec::Lzss(5))?;
    let t_lzss = t0.elapsed().as_secs_f64();
    println!(
        "\nprep cost: plain {:.3}s vs +LZSS {:.3}s ({:.1}x slowdown; paper 4.3x)",
        t_plain,
        t_lzss,
        t_lzss / t_plain
    );
    println!(
        "compression ratio: {:.2}x (paper 2.8x); stored {} -> {}",
        packed.ratio(),
        human_bytes(plain.stored_bytes),
        human_bytes(packed.stored_bytes)
    );

    // real read path ± compression
    let (bw_plain, _) = serve(Codec::None, &files)?;
    let (bw_comp, ratio) = serve(Codec::Lzss(5), &files)?;
    println!(
        "\nin-proc 4-node read path: plain {} vs compressed {} ({:+.1}%, ratio {:.2}x)",
        human_rate(bw_plain),
        human_rate(bw_comp),
        (bw_comp / bw_plain - 1.0) * 100.0,
        ratio
    );

    // simulated Fig 10 trend
    println!("\nsimulated GPU-cluster SRGAN (Fig 10):");
    let rows = fanstore::experiments::compression::run_fig10();
    fanstore::experiments::compression::report_fig10(&rows);
    println!("srgan_compression OK");
    Ok(())
}
