//! Quickstart: the end-to-end validation driver.
//!
//! Proves all three layers compose on a real small workload:
//!   1. generate a labelled image dataset (10 classes, raw u8 files),
//!   2. pack it into FanStore partitions and launch a 4-node in-process
//!      cluster (real worker threads, real message passing, real bytes),
//!   3. train the CNN surrogate for a few hundred steps — every mini-batch
//!      file read goes open→locate→(local|remote fetch)→cache→decode, every
//!      train step is one PJRT call into the AOT-compiled JAX graph whose
//!      HLO embeds the Pallas preprocess + tile-matmul kernels,
//!   4. log the loss curve, validate on the replicated test set, write
//!      checkpoints back through the VFS (visible-until-close),
//!   5. print the per-node I/O accounting.
//!
//! Run: `make artifacts && cargo run --release --offline --example quickstart`
//! The run recorded in EXPERIMENTS.md §End-to-end used the defaults below.

use fanstore::config::ClusterConfig;
use fanstore::coordinator::Cluster;
use fanstore::runtime::Engine;
use fanstore::trainer::data::gen_classification_dataset;
use fanstore::trainer::{train_cnn, DatasetView, TrainConfig};
use fanstore::vfs::Vfs;

fn main() -> fanstore::Result<()> {
    let artifacts = std::env::var("FANSTORE_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    println!("[1/5] loading PJRT engine from {artifacts}/ ...");
    let engine = Engine::load_subset(&artifacts, &["cnn_train_step", "cnn_eval_step"])?;

    println!("[2/5] generating dataset: 1280 train + 320 test images (32x32x3 u8 files)");
    let mut files = gen_classification_dataset(1280, "train", 11);
    files.extend(gen_classification_dataset(320, "test", 23));

    println!("[3/5] packing partitions + launching 4-node cluster (test/ replicated)");
    let cfg = ClusterConfig {
        nodes: 4,
        partitions: 8,
        replicate_dirs: vec!["test".into()],
        ..Default::default()
    };
    let mount = cfg.mount.clone();
    let cluster = Cluster::launch(&files, cfg)?;
    println!(
        "      prep: {} files, {} raw",
        cluster.prep_stats.files,
        fanstore::util::human_bytes(cluster.prep_stats.raw_bytes)
    );

    let train_paths: Vec<String> = files
        .iter()
        .filter(|f| f.path.starts_with("train"))
        .map(|f| format!("{mount}/{}", f.path))
        .collect();
    let test_paths: Vec<String> = files
        .iter()
        .filter(|f| f.path.starts_with("test"))
        .map(|f| format!("{mount}/{}", f.path))
        .collect();

    println!("[4/5] training: 4 data-parallel replicas, 3 epochs (~120 steps x 32 batch)");
    let tc = TrainConfig {
        epochs: 3,
        max_steps_per_epoch: None,
        lr: 0.05,
        view: DatasetView::Global,
        seed: 7,
        checkpoint: true,
        flip_prob: 0.0,
        prefetch: true,
    };
    let log = train_cnn(&cluster, &engine, &train_paths, &test_paths, &tc)?;
    println!("      loss curve (every 8th step):");
    for (i, l) in log.step_losses.iter().enumerate().step_by(8) {
        println!("        step {i:>4}: {l:.4}");
    }
    for e in &log.epochs {
        println!(
            "      epoch {}: loss {:.4}, train acc {:.1}%, TEST ACC {:.1}%, {} file reads in {:.2}s ({:.0} files/s)",
            e.epoch,
            e.mean_loss,
            e.train_acc * 100.0,
            e.test_acc * 100.0,
            e.files_read,
            e.seconds,
            e.files_read as f64 / e.seconds
        );
    }

    // read a checkpoint back through the global namespace from another node
    let mut vfs = cluster.client(3);
    let ckpts = vfs.readdir("/ckpt")?;
    println!("[5/5] checkpoints visible cluster-wide: {ckpts:?}");
    let blob = vfs.read_all(&format!("/ckpt/{}", ckpts.last().unwrap()))?;
    println!("      last checkpoint: {} bytes", blob.len());

    let report = cluster.shutdown();
    println!("per-node I/O accounting:");
    for (i, s) in report.per_node.iter().enumerate() {
        println!(
            "  node {i}: {} local reads, {} remote fetches ({}), {} outputs",
            s.local_reads,
            s.remote_reads_issued,
            fanstore::util::human_bytes(s.bytes_fetched_remote),
            s.outputs_committed
        );
    }
    let final_acc = log.final_test_acc();
    println!("FINAL TEST ACCURACY: {:.1}%", final_acc * 100.0);
    assert!(final_acc > 0.5, "training failed to learn");
    println!("quickstart OK");
    Ok(())
}
