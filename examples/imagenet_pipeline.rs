//! ImageNet-style I/O pipeline study (the paper's motivating workload, §2).
//!
//! Two parts:
//!   * **real**: an in-process FanStore cluster serves an ImageNet-profile
//!     dataset (Table 2 statistics, scaled) to concurrent reader threads on
//!     every node — wall-clock bandwidth/files/s of this host's actual
//!     FanStore code path at 1..8 nodes;
//!   * **simulated**: the same workload priced on the virtual-time testbed
//!     models (Fig 3/5-style), so the two can be compared side by side.
//!
//! Run: `cargo run --release --offline --example imagenet_pipeline`

use fanstore::config::ClusterConfig;
use fanstore::coordinator::Cluster;
use fanstore::experiments::iosim::{run_benchmark, FanStoreSim, SimDataset};
use fanstore::net::fabric::Fabric;
use fanstore::util::{human_bytes, human_rate};
use fanstore::vfs::Vfs;
use fanstore::workload::datasets::DatasetSpec;

fn real_run(nodes: u32, files: usize) -> fanstore::Result<(f64, f64, f64)> {
    let spec = DatasetSpec::imagenet();
    let data = spec.generate(files, 8, 77); // ~13 KiB mean at divisor 8
    let cfg = ClusterConfig {
        nodes,
        partitions: nodes * 4,
        ..Default::default()
    };
    let mount = cfg.mount.clone();
    let cluster = Cluster::launch(&data, cfg)?;
    let paths: Vec<String> = data
        .iter()
        .map(|f| format!("{mount}/{}", f.path))
        .collect();
    let t0 = std::time::Instant::now();
    let mut handles = Vec::new();
    for node in 0..nodes {
        // 4 reader threads per node, as Keras defaults to (§3.3)
        for t in 0..4u32 {
            let mut vfs = cluster.client(node);
            let paths = paths.clone();
            handles.push(std::thread::spawn(move || -> fanstore::Result<u64> {
                let mut bytes = 0u64;
                let mut i = t as usize;
                while i < paths.len() {
                    bytes += vfs.read_all(&paths[i])?.len() as u64;
                    i += 4;
                }
                Ok(bytes)
            }));
        }
    }
    let mut total = 0u64;
    for h in handles {
        total += h.join().expect("reader")?;
    }
    let secs = t0.elapsed().as_secs_f64();
    let report = cluster.shutdown();
    let remote: u64 = report.per_node.iter().map(|s| s.remote_reads_issued).sum();
    let reads = files as u64 * nodes as u64;
    Ok((
        total as f64 / secs,
        reads as f64 / secs,
        remote as f64 / reads as f64,
    ))
}

fn main() -> fanstore::Result<()> {
    println!("ImageNet-profile pipeline: {} files full-scale, mean file {}",
        DatasetSpec::imagenet().full_files,
        human_bytes(DatasetSpec::imagenet().mean_file_size()));

    println!("\n-- real in-proc cluster (wall clock, this host) --");
    println!("   (all simulated nodes share THIS host's cores: aggregate wall-clock");
    println!("   bandwidth cannot scale with node count here — what scales is shown");
    println!("   by the virtual-time model below; this section validates the real");
    println!("   code path and the locality split)");
    println!("{:>6} {:>14} {:>12} {:>9}", "nodes", "agg BW", "files/s", "remote%");
    for nodes in [1u32, 2, 4, 8] {
        let (bw, fps, remote) = real_run(nodes, 600)?;
        println!(
            "{nodes:>6} {:>14} {fps:>12.0} {:>8.1}%",
            human_rate(bw),
            remote * 100.0
        );
    }

    println!("\n-- simulated 2018 testbed (virtual time, Fig 5 model) --");
    println!("{:>6} {:>14} {:>12}", "nodes", "agg BW", "files/s");
    for nodes in [1u32, 4, 8, 16] {
        let parts = 48.max(nodes);
        let ds = SimDataset::uniform(4096, 128 << 10, parts, 1.0);
        let mut sim = FanStoreSim::new(nodes, parts, 1, Fabric::fdr_infiniband());
        let r = run_benchmark(&mut sim, &ds, nodes, 4);
        println!(
            "{nodes:>6} {:>14} {:>12.0}",
            human_rate(r.bandwidth_mbs() * 1e6),
            r.files_per_sec()
        );
    }
    println!("\nimagenet_pipeline OK");
    Ok(())
}
