"""L2 correctness: the train-step graphs learn on learnable synthetic data,
and their shapes match the AOT manifest contract."""

import numpy as np
import jax.numpy as jnp

from compile import model


def _synthetic_images(rng, n, classes=10):
    """Class-separable u8 images: class k has a bright kth vertical band."""
    imgs = rng.randint(0, 64, (n, model.CNN_HW, model.CNN_HW, 3), dtype=np.uint8)
    labels = rng.randint(0, classes, n).astype(np.int32)
    band = model.CNN_HW // classes
    for i, lbl in enumerate(labels):
        imgs[i, :, lbl * band : (lbl + 1) * band, :] = 220
    return imgs, labels


def test_cnn_train_step_learns():
    rng = np.random.RandomState(0)
    params = model.cnn_init()
    imgs, labels = _synthetic_images(rng, model.CNN_BATCH)
    flip = np.zeros(model.CNN_BATCH, np.int32)
    first_loss = None
    for step in range(30):
        out = model.cnn_train_step(
            *params,
            jnp.asarray(imgs),
            jnp.asarray(labels),
            jnp.asarray(flip),
            model.MEAN,
            model.STD,
            jnp.float32(0.05),
        )
        params = out[: len(model.CNN_PARAM_NAMES)]
        loss = float(out[-2])
        if first_loss is None:
            first_loss = loss
    assert loss < first_loss * 0.5, f"loss did not drop: {first_loss} -> {loss}"


def test_cnn_eval_step_counts():
    rng = np.random.RandomState(1)
    params = model.cnn_init()
    imgs, labels = _synthetic_images(rng, model.CNN_BATCH)
    loss, correct = model.cnn_eval_step(
        *params, jnp.asarray(imgs), jnp.asarray(labels), model.MEAN, model.STD
    )
    assert 0.0 <= float(correct) <= model.CNN_BATCH
    assert np.isfinite(float(loss))


def test_lstm_train_step_learns():
    rng = np.random.RandomState(2)
    params = model.lstm_init()
    # disruptions = strong mean signal in the last quarter of the window
    x = rng.randn(model.LSTM_BATCH, model.LSTM_T, model.LSTM_F).astype(np.float32)
    y = rng.randint(0, 2, model.LSTM_BATCH).astype(np.float32)
    x[y == 1, -model.LSTM_T // 4 :, :] += 2.5
    first_loss = None
    for _ in range(40):
        out = model.lstm_train_step(
            *params, jnp.asarray(x), jnp.asarray(y), jnp.float32(0.1)
        )
        params = out[: len(model.LSTM_PARAM_NAMES)]
        loss = float(out[-1])
        if first_loss is None:
            first_loss = loss
    assert loss < first_loss * 0.7, f"loss did not drop: {first_loss} -> {loss}"


def test_gan_init_step_learns():
    rng = np.random.RandomState(3)
    params = model.gan_init_params()
    hr = rng.uniform(0, 1, (model.GAN_BATCH, 32, 32, 3)).astype(np.float32)
    lr_img = hr[:, ::2, ::2, :]  # 4x undersampling as in the paper's SRGAN
    first_loss = None
    for _ in range(30):
        out = model.gan_init_step(
            *params, jnp.asarray(lr_img), jnp.asarray(hr), jnp.float32(0.01)
        )
        params = out[: len(model.GAN_PARAM_NAMES)]
        loss = float(out[-1])
        if first_loss is None:
            first_loss = loss
    assert loss < first_loss, f"mse did not drop: {first_loss} -> {loss}"


def test_preprocess_batch_shape():
    imgs = np.zeros((model.CNN_BATCH, model.CNN_HW, model.CNN_HW, 3), np.uint8)
    flip = np.zeros(model.CNN_BATCH, np.int32)
    (out,) = model.preprocess_batch(jnp.asarray(imgs), jnp.asarray(flip))
    assert out.shape == imgs.shape and out.dtype == jnp.float32


def test_gan_generate_upscales_2x():
    params = model.gan_init_params()
    lr_img = jnp.zeros((2, model.GAN_LR_HW, model.GAN_LR_HW, 3), jnp.float32)
    sr = model.gan_generate(params, lr_img)
    assert sr.shape == (2, model.GAN_LR_HW * 2, model.GAN_LR_HW * 2, 3)
