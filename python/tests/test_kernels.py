"""L1 correctness: Pallas kernels vs pure-jnp oracles (ref.py).

Hypothesis sweeps shapes and values; interpret-mode Pallas is slow, so
example counts are kept modest but cover the tiling envelope the models use.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.preprocess import preprocess
from compile.kernels.tile_matmul import tile_matmul, matmul_any, dmatmul

SET = dict(max_examples=12, deadline=None)


# ---------------------------------------------------------------------------
# preprocess
# ---------------------------------------------------------------------------


@settings(**SET)
@given(
    b=st.integers(1, 6),
    h=st.integers(1, 12),
    w=st.integers(1, 12),
    c=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_preprocess_matches_ref(b, h, w, c, seed):
    rng = np.random.RandomState(seed)
    img = rng.randint(0, 256, (b, h, w, c), dtype=np.uint8)
    mean = rng.uniform(0, 255, c).astype(np.float32)
    std = rng.uniform(1, 128, c).astype(np.float32)
    flip = rng.randint(0, 2, b).astype(np.int32)
    got = preprocess(
        jnp.asarray(img), jnp.asarray(mean), jnp.asarray(std), jnp.asarray(flip)
    )
    want = ref.preprocess_ref(
        jnp.asarray(img), jnp.asarray(mean), jnp.asarray(std), jnp.asarray(flip)
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6)


def test_preprocess_all_flip():
    img = np.arange(2 * 4 * 4 * 3, dtype=np.uint8).reshape(2, 4, 4, 3)
    mean = np.zeros(3, np.float32)
    std = np.ones(3, np.float32)
    flip = np.ones(2, np.int32)
    got = np.asarray(
        preprocess(jnp.asarray(img), jnp.asarray(mean), jnp.asarray(std), jnp.asarray(flip))
    )
    np.testing.assert_allclose(got, img[:, :, ::-1, :].astype(np.float32))


def test_preprocess_no_flip_is_normalize():
    rng = np.random.RandomState(3)
    img = rng.randint(0, 256, (3, 5, 7, 3), dtype=np.uint8)
    mean = np.array([10.0, 20.0, 30.0], np.float32)
    std = np.array([2.0, 4.0, 8.0], np.float32)
    flip = np.zeros(3, np.int32)
    got = np.asarray(
        preprocess(jnp.asarray(img), jnp.asarray(mean), jnp.asarray(std), jnp.asarray(flip))
    )
    want = (img.astype(np.float32) - mean) / std
    np.testing.assert_allclose(got, want, rtol=1e-6)


# ---------------------------------------------------------------------------
# tile_matmul
# ---------------------------------------------------------------------------


@settings(**SET)
@given(
    mi=st.integers(1, 4),
    ni=st.integers(1, 4),
    ki=st.integers(1, 4),
    bm=st.sampled_from([8, 16, 32]),
    bn=st.sampled_from([8, 16, 32]),
    bk=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_tile_matmul_matches_ref(mi, ni, ki, bm, bn, bk, seed):
    m, n, k = mi * bm, ni * bn, ki * bk
    rng = np.random.RandomState(seed)
    a = rng.randn(m, k).astype(np.float32)
    b = rng.randn(k, n).astype(np.float32)
    got = tile_matmul(jnp.asarray(a), jnp.asarray(b), bm=bm, bn=bn, bk=bk)
    want = ref.matmul_ref(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_tile_matmul_rejects_ragged():
    a = jnp.zeros((33, 64), jnp.float32)
    b = jnp.zeros((64, 32), jnp.float32)
    with pytest.raises(AssertionError):
        tile_matmul(a, b, bm=32, bn=32, bk=32)


def test_matmul_any_fallback_shape():
    rng = np.random.RandomState(0)
    a = rng.randn(7, 13).astype(np.float32)  # primes: no clean tile
    b = rng.randn(13, 11).astype(np.float32)
    got = matmul_any(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_allclose(np.asarray(got), a @ b, rtol=1e-5, atol=1e-5)


def test_matmul_any_tiled_shape():
    rng = np.random.RandomState(1)
    a = rng.randn(64, 128).astype(np.float32)
    b = rng.randn(128, 64).astype(np.float32)
    got = matmul_any(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_allclose(np.asarray(got), a @ b, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# dmatmul (custom VJP through the Pallas kernel)
# ---------------------------------------------------------------------------


def test_dmatmul_grads_match_jnp():
    rng = np.random.RandomState(7)
    a = jnp.asarray(rng.randn(32, 64).astype(np.float32))
    b = jnp.asarray(rng.randn(64, 32).astype(np.float32))

    def f_pallas(a, b):
        return jnp.sum(jnp.sin(dmatmul(a, b)))

    def f_ref(a, b):
        return jnp.sum(jnp.sin(a @ b))

    ga_p, gb_p = jax.grad(f_pallas, argnums=(0, 1))(a, b)
    ga_r, gb_r = jax.grad(f_ref, argnums=(0, 1))(a, b)
    np.testing.assert_allclose(np.asarray(ga_p), np.asarray(ga_r), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(gb_p), np.asarray(gb_r), rtol=1e-4, atol=1e-4)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_dmatmul_forward_sweep(seed):
    rng = np.random.RandomState(seed)
    a = rng.randn(16, 32).astype(np.float32)
    b = rng.randn(32, 16).astype(np.float32)
    got = dmatmul(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_allclose(np.asarray(got), a @ b, rtol=1e-4, atol=1e-4)
