"""AOT path: manifest grammar, HLO text validity, params binary sizes."""

import os

import numpy as np
import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    aot.build(str(out))
    return str(out)


def _parse_manifest(path):
    arts = {}
    cur = None
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line or line.startswith("#"):
                continue
            tok = line.split()
            if tok[0] == "artifact":
                cur = {"name": tok[1], "in": [], "out": [], "params": None}
                arts[tok[1]] = cur
            elif tok[0] == "hlo":
                cur["hlo"] = tok[1]
            elif tok[0] == "in":
                cur["in"].append(tuple(tok[1:]))
            elif tok[0] == "out":
                cur["out"].append(tuple(tok[1:]))
            elif tok[0] == "params":
                cur["params"] = tok[1]
            elif tok[0] == "end":
                cur = None
            else:
                raise AssertionError(f"unknown manifest token {tok[0]}")
    return arts


def test_manifest_covers_all_specs(built):
    arts = _parse_manifest(os.path.join(built, "manifest.txt"))
    assert set(arts) == set(model.SPECS)


def test_hlo_text_is_parseable_hlo(built):
    arts = _parse_manifest(os.path.join(built, "manifest.txt"))
    for art in arts.values():
        text = open(os.path.join(built, art["hlo"])).read()
        assert text.startswith("HloModule"), art["name"]
        assert "ENTRY" in text, art["name"]


def test_params_bin_sizes_match_shapes(built):
    arts = _parse_manifest(os.path.join(built, "manifest.txt"))
    for art in arts.values():
        if art["params"] is None:
            continue
        n_param_bytes = 0
        for name, dtype, dims, kind in art["in"]:
            if kind != "param":
                continue
            assert dtype == "f32", "params are f32 by contract"
            count = 1
            if dims != "scalar":
                for d in dims.split("x"):
                    count *= int(d)
            n_param_bytes += 4 * count
        size = os.path.getsize(os.path.join(built, art["params"]))
        assert size == n_param_bytes, art["name"]


def test_train_steps_return_params_first(built):
    arts = _parse_manifest(os.path.join(built, "manifest.txt"))
    for art in arts.values():
        n_params = sum(1 for i in art["in"] if i[3] == "param")
        if n_params == 0:
            continue
        # contract: first n_params outputs mirror the param shapes
        for i in range(n_params):
            assert art["in"][i][1:3] == art["out"][i][1:3], (
                art["name"],
                i,
                art["in"][i],
                art["out"][i],
            )


def test_dtypes_in_vocabulary(built):
    arts = _parse_manifest(os.path.join(built, "manifest.txt"))
    for art in arts.values():
        for rec in art["in"] + art["out"]:
            assert rec[1] in {"u8", "i32", "f32"}
