"""L1 Pallas kernel: image decode + normalize + flip augmentation.

This is the per-file CPU work of the data pipeline — the compute that
FanStore's I/O path has to keep fed.  Each dataset file holds one raw u8
image; after the VFS read, this kernel turns the bytes into a normalized f32
tensor and applies the horizontal-flip augmentation selected by the trainer.

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid runs over the batch
dimension, so each program instance streams one [H, W, C] u8 image block
HBM→VMEM, does element-wise VPU work, and writes the f32 block back.  The
BlockSpec pipeline replaces the host-side prefetch threads the paper's
frameworks (Keras, 4 I/O threads/process) used.  interpret=True is mandatory
here: the CPU PJRT plugin cannot run Mosaic custom-calls.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _preprocess_kernel(img_ref, mean_ref, std_ref, flip_ref, out_ref):
    """One grid step = one image.

    img_ref:  u8  [H, W, C] block in VMEM
    mean_ref: f32 [C]
    std_ref:  f32 [C]
    flip_ref: i32 []    (this image's flip flag, scalar block)
    out_ref:  f32 [H, W, C]
    """
    x = img_ref[...].astype(jnp.float32)
    x = (x - mean_ref[...][None, None, :]) / std_ref[...][None, None, :]
    flipped = x[:, ::-1, :]
    flip = flip_ref[...]
    out_ref[...] = jnp.where(flip == 0, x, flipped)


@functools.partial(jax.jit, static_argnames=("interpret",))
def preprocess(images_u8, mean, std, flip, *, interpret=True):
    """Normalize + flip a batch of u8 images with a Pallas kernel.

    Args:
      images_u8: u8 [B, H, W, C]
      mean, std: f32 [C] channel statistics on the 0-255 scale
      flip:      i32 [B] per-image horizontal-flip flags
    Returns:
      f32 [B, H, W, C]
    """
    b, h, w, c = images_u8.shape
    return pl.pallas_call(
        _preprocess_kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((None, h, w, c), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((c,), lambda i: (0,)),
            pl.BlockSpec((c,), lambda i: (0,)),
            pl.BlockSpec((None,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((None, h, w, c), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, w, c), jnp.float32),
        interpret=interpret,
    )(images_u8, mean, std, flip)
