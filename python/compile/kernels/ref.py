"""Pure-jnp reference oracles for the Pallas kernels.

These are the ground truth the kernels in this package are tested against
(python/tests/test_kernels.py).  They are also used directly by model.py when
a shape falls outside the kernels' tiling constraints.
"""

from __future__ import annotations

import jax.numpy as jnp


def preprocess_ref(images_u8, mean, std, flip):
    """Decode + normalize + horizontal-flip augmentation, pure jnp.

    Args:
      images_u8: uint8 [B, H, W, C] raw pixels as stored in the dataset files.
      mean:      f32 [C] per-channel mean (0-255 scale).
      std:       f32 [C] per-channel std  (0-255 scale).
      flip:      i32 [B] 1 = flip the image horizontally, 0 = keep.

    Returns:
      f32 [B, H, W, C] normalized images.
    """
    x = images_u8.astype(jnp.float32)
    x = (x - mean[None, None, None, :]) / std[None, None, None, :]
    flipped = x[:, :, ::-1, :]
    keep = (flip == 0)[:, None, None, None]
    return jnp.where(keep, x, flipped)


def matmul_ref(a, b):
    """f32 matmul oracle for the tiled Pallas matmul."""
    return jnp.matmul(a, b)


def _sigmoid(x):
    return 1.0 / (1.0 + jnp.exp(-x))


def lstm_cell_ref(x, h, c, wx, wh, b):
    """Single LSTM cell step, gate order (i, f, g, o).

    x: [B, F], h/c: [B, H], wx: [F, 4H], wh: [H, 4H], b: [4H].
    """
    z = x @ wx + h @ wh + b
    hidden = h.shape[-1]
    i = _sigmoid(z[:, 0 * hidden : 1 * hidden])
    f = _sigmoid(z[:, 1 * hidden : 2 * hidden])
    g = jnp.tanh(z[:, 2 * hidden : 3 * hidden])
    o = _sigmoid(z[:, 3 * hidden : 4 * hidden])
    c_new = f * c + i * g
    h_new = o * jnp.tanh(c_new)
    return h_new, c_new
