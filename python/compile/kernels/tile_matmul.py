"""L1 Pallas kernel: MXU-tiled matmul with a VMEM accumulator.

Used by model.py for the dense layers of the CNN / LSTM / GAN surrogates so
the training-step HLO exercises a Pallas kernel end to end.

TPU mapping: (bm, bn) output tiles with a K-panel loop as the innermost grid
dimension; the f32 accumulator lives in VMEM scratch across K steps (revisited
output block), which is the Pallas idiom for the paper-era "stream panels
through the systolic array" schedule.  Tiles default to 128 to line up with
the MXU; shapes must divide by the chosen blocks (model.py pads or falls back
to ref.matmul_ref otherwise).  interpret=True for CPU-PJRT execution.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, k_steps):
    """Grid = (M/bm, N/bn, K/bk); K is innermost so acc persists per (i, j)."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == k_steps - 1)
    def _flush():
        o_ref[...] = acc_ref[...]


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "interpret")
)
def tile_matmul(a, b, *, bm=128, bn=128, bk=128, interpret=True):
    """C = A @ B with (bm, bn, bk) tiling.

    A: f32 [M, K], B: f32 [K, N], M % bm == N % bn == K % bk == 0.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        f"shape ({m},{k})x({k},{n}) not divisible by tiles ({bm},{bn},{bk})"
    )
    k_steps = k // bk
    kernel = functools.partial(_matmul_kernel, k_steps=k_steps)
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu_scratch(bm, bn)],
        interpret=interpret,
    )(a, b)


def pltpu_scratch(bm, bn):
    """VMEM f32 scratch accumulator; ANY-memory fallback under interpret."""
    try:  # pragma: no cover - depends on jax version
        from jax.experimental.pallas import tpu as pltpu

        return pltpu.VMEM((bm, bn), jnp.float32)
    except Exception:  # pragma: no cover
        return pl.MemoryRef((bm, bn), jnp.float32)


@jax.custom_vjp
def dmatmul(a, b):
    """Differentiable Pallas matmul.

    Pallas interpret-mode kernels do not support reverse-mode AD directly, so
    we supply the well-known matmul VJP — itself computed with the Pallas
    kernel, which keeps the MXU tile kernel on both the forward and backward
    hot paths of the lowered train-step HLO.
    """
    return matmul_any(a, b)


def _dmatmul_fwd(a, b):
    return matmul_any(a, b), (a, b)


def _dmatmul_bwd(res, dc):
    a, b = res
    da = matmul_any(dc, b.T)  # [M,N]x[N,K] -> [M,K]
    db = matmul_any(a.T, dc)  # [K,M]x[M,N] -> [K,N]
    return da, db


dmatmul.defvjp(_dmatmul_fwd, _dmatmul_bwd)


def matmul_any(a, b, *, interpret=True):
    """tile_matmul when the shape tiles cleanly, jnp fallback otherwise.

    Keeps model.py free of shape bookkeeping: small dense layers (e.g. the
    10-way logits) fall back to XLA's own matmul, big ones go through the
    Pallas kernel with the largest clean tile ≤128.
    """
    m, k = a.shape
    n = b.shape[1]

    def best(dim):
        for t in (128, 64, 32, 16, 8):
            if dim % t == 0:
                return t
        return None

    bm, bn, bk = best(m), best(n), best(k)
    if bm and bn and bk:
        return tile_matmul(a, b, bm=bm, bn=bn, bk=bk, interpret=interpret)
    return jnp.matmul(a, b)
