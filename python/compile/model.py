"""L2: JAX training-step graphs for the three FanStore application surrogates.

The paper evaluates FanStore with three real applications (Table 1):
ResNet-50 (CNN), SRGAN (GAN), and FRNN (RNN/LSTM).  Their full-scale models
need GPUs the testbed does not have, so we build scale-faithful surrogates —
same architecture family, same training-step structure (fwd, bwd, SGD) —
sized so the compute:I/O ratio can be calibrated by the Rust simulator
(DESIGN.md §1).

Every function here is lowered ONCE by aot.py to HLO text and executed from
the Rust coordinator via PJRT; Python is never on the request path.  Dense
layers go through the Pallas `dmatmul` kernel so both fwd and bwd HLO contain
the L1 kernel; convolutions use lax.conv (XLA's native conv is the right tool
on every backend, and the paper's hot spot is I/O, not conv).

All steps take and return a flat tuple of arrays (params..., aux...) because
the PJRT boundary is positional.  See `SPECS` at the bottom for the manifest
consumed by aot.py and the Rust runtime.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from compile.kernels.preprocess import preprocess
from compile.kernels.tile_matmul import dmatmul
from compile.kernels import ref

# ---------------------------------------------------------------------------
# Shared sizes (kept in sync with rust/src/runtime via the manifest emitted
# by aot.py).
# ---------------------------------------------------------------------------

CNN_BATCH = 32
CNN_HW = 32  # image height == width
CNN_CLASSES = 10

LSTM_BATCH = 32
LSTM_T = 16  # time steps per sample window
LSTM_F = 16  # diagnostic signals per step
LSTM_H = 64

GAN_BATCH = 8
GAN_LR_HW = 16  # low-res input, upscaled 2x to 32

# ImageNet-ish channel statistics on the 0-255 scale.
MEAN = jnp.array([125.3, 123.0, 113.9], jnp.float32)
STD = jnp.array([63.0, 62.1, 66.7], jnp.float32)


def _dense(x, w, b):
    """Dense layer through the differentiable Pallas matmul."""
    return dmatmul(x, w) + b


# ---------------------------------------------------------------------------
# CNN (ResNet-50 surrogate): conv-pool x2 + residual block + 2 dense layers.
# ---------------------------------------------------------------------------


def cnn_init(seed=0):
    """Initial CNN parameters (He-scaled), returned as a flat tuple."""
    k = jax.random.PRNGKey(seed)
    ks = jax.random.split(k, 6)

    def he(key, shape, fan_in):
        return (jax.random.normal(key, shape) * jnp.sqrt(2.0 / fan_in)).astype(
            jnp.float32
        )

    conv1 = he(ks[0], (3, 3, 3, 16), 27)
    conv2 = he(ks[1], (3, 3, 16, 32), 144)
    conv3 = he(ks[2], (3, 3, 32, 32), 288)  # residual block conv
    fc1_w = he(ks[3], (2048, 128), 2048)  # 8*8*32 = 2048 after two pools
    fc1_b = jnp.zeros((128,), jnp.float32)
    fc2_w = he(ks[4], (128, CNN_CLASSES), 128)
    fc2_b = jnp.zeros((CNN_CLASSES,), jnp.float32)
    return (conv1, conv2, conv3, fc1_w, fc1_b, fc2_w, fc2_b)


CNN_PARAM_NAMES = ("conv1", "conv2", "conv3", "fc1_w", "fc1_b", "fc2_w", "fc2_b")


def _conv(x, w, stride=1):
    return lax.conv_general_dilated(
        x, w, (stride, stride), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
    )


def _pool2(x):
    return lax.reduce_window(
        x, -jnp.inf, lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def cnn_logits(params, x):
    """Forward pass, f32 [B,H,W,C] -> [B, classes]."""
    conv1, conv2, conv3, fc1_w, fc1_b, fc2_w, fc2_b = params
    h = jax.nn.relu(_conv(x, conv1))
    h = _pool2(h)  # 16x16x16
    h = jax.nn.relu(_conv(h, conv2))
    h = _pool2(h)  # 8x8x32
    h = h + jax.nn.relu(_conv(h, conv3))  # residual block (ResNet's signature)
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(_dense(h, fc1_w, fc1_b))
    return jnp.matmul(h, fc2_w) + fc2_b  # 10-way logits: too thin to tile


def _xent(logits, labels):
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def cnn_train_step(*args):
    """(params..., images_u8, labels, flip, mean, std, lr) ->
    (new_params..., loss, acc).

    The Pallas preprocess kernel runs inside the step (before grad — only
    params are differentiated), so one PJRT call does decode+normalize+
    augment+fwd+bwd+SGD: the whole per-iteration compute of §3.1.

    `mean`/`std` are the normalization statistics maintained by the caller
    (the trainer keeps per-node running stats, like framework BatchNorm —
    they are NOT gradient-allreduced, which is what the Fig 1 partitioned
    view trips over).
    """
    n = len(CNN_PARAM_NAMES)
    params = args[:n]
    images_u8, labels, flip, mean, std, lr = args[n:]
    x = preprocess(images_u8, mean, std, flip)

    def loss_fn(p):
        logits = cnn_logits(p, x)
        return _xent(logits, labels), logits

    (loss, logits), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
    acc = jnp.mean((jnp.argmax(logits, axis=1) == labels).astype(jnp.float32))
    new_params = tuple(p - lr * g for p, g in zip(params, grads))
    return (*new_params, loss, acc)


def cnn_eval_step(*args):
    """(params..., images_u8, labels, mean, std) -> (loss, correct_count).

    Inference only — tile_matmul runs without the VJP wrapper.  Evaluation
    normalizes with the *rank-0* statistics, as Horovod checkpoints do.
    """
    n = len(CNN_PARAM_NAMES)
    params = args[:n]
    images_u8, labels, mean, std = args[n:]
    flip = jnp.zeros((images_u8.shape[0],), jnp.int32)
    x = preprocess(images_u8, mean, std, flip)
    logits = cnn_logits(params, x)
    loss = _xent(logits, labels)
    correct = jnp.sum((jnp.argmax(logits, axis=1) == labels).astype(jnp.float32))
    return (loss, correct)


# ---------------------------------------------------------------------------
# LSTM (FRNN surrogate): disruption prediction over diagnostic time series.
# ---------------------------------------------------------------------------


def lstm_init(seed=1):
    k = jax.random.PRNGKey(seed)
    ks = jax.random.split(k, 4)
    scale_x = jnp.sqrt(1.0 / LSTM_F)
    scale_h = jnp.sqrt(1.0 / LSTM_H)
    wx = (jax.random.normal(ks[0], (LSTM_F, 4 * LSTM_H)) * scale_x).astype(jnp.float32)
    wh = (jax.random.normal(ks[1], (LSTM_H, 4 * LSTM_H)) * scale_h).astype(jnp.float32)
    b = jnp.zeros((4 * LSTM_H,), jnp.float32)
    # forget-gate bias = 1 (standard LSTM trick)
    b = b.at[LSTM_H : 2 * LSTM_H].set(1.0)
    out_w = (jax.random.normal(ks[2], (LSTM_H, 1)) * scale_h).astype(jnp.float32)
    out_b = jnp.zeros((1,), jnp.float32)
    return (wx, wh, b, out_w, out_b)


LSTM_PARAM_NAMES = ("wx", "wh", "b", "out_w", "out_b")


def lstm_logit(params, x_seq):
    """x_seq: f32 [B, T, F] -> disruption logit [B]."""
    wx, wh, b, out_w, out_b = params
    bsz = x_seq.shape[0]
    h0 = jnp.zeros((bsz, LSTM_H), jnp.float32)
    c0 = jnp.zeros((bsz, LSTM_H), jnp.float32)

    def step(carry, x_t):
        h, c = carry
        h, c = ref.lstm_cell_ref(x_t, h, c, wx, wh, b)
        return (h, c), None

    (h, _), _ = lax.scan(step, (h0, c0), jnp.swapaxes(x_seq, 0, 1))
    return (jnp.matmul(h, out_w) + out_b)[:, 0]


def lstm_train_step(*args):
    """(params..., x_seq, y, lr) -> (new_params..., loss)."""
    n = len(LSTM_PARAM_NAMES)
    params = args[:n]
    x_seq, y, lr = args[n:]

    def loss_fn(p):
        logit = lstm_logit(p, x_seq)
        # numerically stable BCE-with-logits
        return jnp.mean(
            jnp.maximum(logit, 0) - logit * y + jnp.log1p(jnp.exp(-jnp.abs(logit)))
        )

    loss, grads = jax.value_and_grad(loss_fn)(params)
    new_params = tuple(p - lr * g for p, g in zip(params, grads))
    return (*new_params, loss)


# ---------------------------------------------------------------------------
# GAN generator init stage (SRGAN surrogate): 2x super-resolution, MSE loss.
# SRGAN's "initialization" epochs train the generator alone on pixel loss —
# exactly what this step does.
# ---------------------------------------------------------------------------


def gan_init_params(seed=2):
    k = jax.random.PRNGKey(seed)
    ks = jax.random.split(k, 3)

    def he(key, shape, fan_in):
        return (jax.random.normal(key, shape) * jnp.sqrt(2.0 / fan_in)).astype(
            jnp.float32
        )

    g1 = he(ks[0], (3, 3, 3, 32), 27)
    g2 = he(ks[1], (3, 3, 32, 12), 288)  # 12 = 3 channels * 2*2 pixel-shuffle
    g3 = he(ks[2], (3, 3, 3, 3), 27)
    return (g1, g2, g3)


GAN_PARAM_NAMES = ("g1", "g2", "g3")


def gan_generate(params, lr_img):
    """lr_img: f32 [B, 16, 16, 3] -> sr [B, 32, 32, 3] via pixel shuffle."""
    g1, g2, g3 = params
    h = jax.nn.relu(_conv(lr_img, g1))
    h = _conv(h, g2)  # [B, 16, 16, 12]
    b, hh, ww, _ = h.shape
    # depth-to-space (pixel shuffle) r=2
    h = h.reshape(b, hh, ww, 2, 2, 3)
    h = h.transpose(0, 1, 3, 2, 4, 5).reshape(b, hh * 2, ww * 2, 3)
    return _conv(jax.nn.relu(h), g3)


def gan_init_step(*args):
    """(params..., lr_img, hr_img, lr) -> (new_params..., mse)."""
    n = len(GAN_PARAM_NAMES)
    params = args[:n]
    lr_img, hr_img, lr = args[n:]

    def loss_fn(p):
        sr = gan_generate(p, lr_img)
        return jnp.mean((sr - hr_img) ** 2)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    new_params = tuple(p - lr * g for p, g in zip(params, grads))
    return (*new_params, loss)


# ---------------------------------------------------------------------------
# Standalone preprocess graph (used by the I/O pipeline benchmarks, where the
# trainer wants decode+normalize without a train step).
# ---------------------------------------------------------------------------


def preprocess_batch(images_u8, flip):
    return (preprocess(images_u8, MEAN, STD, flip),)


# ---------------------------------------------------------------------------
# AOT manifest: name -> (fn, example-args builder, param init fn, param names)
# ---------------------------------------------------------------------------


def _cnn_example_args():
    params = cnn_init()
    images = jnp.zeros((CNN_BATCH, CNN_HW, CNN_HW, 3), jnp.uint8)
    labels = jnp.zeros((CNN_BATCH,), jnp.int32)
    flip = jnp.zeros((CNN_BATCH,), jnp.int32)
    lr = jnp.float32(0.05)
    return (*params, images, labels, flip, MEAN, STD, lr)


def _cnn_eval_example_args():
    params = cnn_init()
    images = jnp.zeros((CNN_BATCH, CNN_HW, CNN_HW, 3), jnp.uint8)
    labels = jnp.zeros((CNN_BATCH,), jnp.int32)
    return (*params, images, labels, MEAN, STD)


def _lstm_example_args():
    params = lstm_init()
    x = jnp.zeros((LSTM_BATCH, LSTM_T, LSTM_F), jnp.float32)
    y = jnp.zeros((LSTM_BATCH,), jnp.float32)
    lr = jnp.float32(0.05)
    return (*params, x, y, lr)


def _gan_example_args():
    params = gan_init_params()
    lr_img = jnp.zeros((GAN_BATCH, GAN_LR_HW, GAN_LR_HW, 3), jnp.float32)
    hr_img = jnp.zeros((GAN_BATCH, GAN_LR_HW * 2, GAN_LR_HW * 2, 3), jnp.float32)
    lr = jnp.float32(0.001)
    return (*params, lr_img, hr_img, lr)


def _preprocess_example_args():
    images = jnp.zeros((CNN_BATCH, CNN_HW, CNN_HW, 3), jnp.uint8)
    flip = jnp.zeros((CNN_BATCH,), jnp.int32)
    return (images, flip)


SPECS = {
    "cnn_train_step": (cnn_train_step, _cnn_example_args, cnn_init, CNN_PARAM_NAMES),
    "cnn_eval_step": (cnn_eval_step, _cnn_eval_example_args, None, None),
    "lstm_train_step": (
        lstm_train_step,
        _lstm_example_args,
        lstm_init,
        LSTM_PARAM_NAMES,
    ),
    "gan_init_step": (
        gan_init_step,
        _gan_example_args,
        gan_init_params,
        GAN_PARAM_NAMES,
    ),
    "preprocess_batch": (preprocess_batch, _preprocess_example_args, None, None),
}
